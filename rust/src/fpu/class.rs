//! The open operation-class registry.
//!
//! Every layer of the system — scheme construction, plan caching, batcher
//! routing, op counters, cluster servability masks, workload mixes, the
//! CLI — iterates or indexes over [`OpClass::ALL`] instead of hard-coding
//! the paper's three IEEE precisions. Adding a served format is therefore
//! one edit here (a variant, its [`FpFormat`] in [`super::format`], and a
//! `civp_chunks` arm in `decomp::scheme`); the rest of the stack sizes
//! itself from [`OpClass::COUNT`].
//!
//! The registry currently serves seven classes, ordered by significand
//! width: bfloat16 (8), binary16 (11), binary32 (24), binary64 (53),
//! binary128 (113), binary256 (237) and binary512 (489). The two
//! sub-single formats extend the paper's §II census *downward*: a bf16
//! significand product fits one `9x9` block and a binary16 product tiles
//! onto the `24x9` block, so the CIVP block set serves them without
//! touching the `24x24` pool. The two wide formats extend it *upward*
//! past the `U128` operand word: their packed values travel as
//! `wideint::PackedBits` and their tile DAGs are where the sub-quadratic
//! `karatsuba24` scheme pays off.

use super::format::{FpFormat, BF16, DOUBLE, FP256, FP512, HALF, QUAD, SINGLE};

/// One served floating-point operation class (a packed interchange format
/// whose multiplications the system batches, executes and accounts).
///
/// ```
/// use civp::fpu::OpClass;
///
/// // The registry drives every class-indexed structure in the stack.
/// assert_eq!(OpClass::COUNT, 7);
/// for (i, class) in OpClass::ALL.into_iter().enumerate() {
///     assert_eq!(class.index(), i);
///     assert_eq!(OpClass::from_index(i), class);
///     assert_eq!(OpClass::parse(class.name()), Some(class));
/// }
/// // Significand widths drive the block-count claims: 8/11/24/53/113/237/489.
/// assert_eq!(OpClass::Half.sig_bits(), 11);
/// assert_eq!(OpClass::Quad.sig_bits(), 113);
/// assert_eq!(OpClass::Fp512.sig_bits(), 489);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    /// bfloat16 — 8-bit significand (one `9x9` block).
    Bf16,
    /// binary16 — 11-bit significand (two `24x9` firings).
    Half,
    /// binary32 — 24-bit significand.
    Single,
    /// binary64 — 53-bit significand.
    Double,
    /// binary128 — 113-bit significand.
    Quad,
    /// binary256 — 237-bit significand (13 CIVP chunks; wide operand word).
    Fp256,
    /// binary512 — 489-bit significand (26 CIVP chunks; wide operand word).
    Fp512,
}

impl OpClass {
    /// All served classes, ascending significand width. This array IS the
    /// registry: every `[T; OpClass::COUNT]` structure in the stack is
    /// indexed by position in it.
    pub const ALL: [OpClass; 7] = [
        OpClass::Bf16,
        OpClass::Half,
        OpClass::Single,
        OpClass::Double,
        OpClass::Quad,
        OpClass::Fp256,
        OpClass::Fp512,
    ];

    /// Number of served classes (sizes the flat arrays everywhere).
    pub const COUNT: usize = Self::ALL.len();

    /// Dense index into class-indexed arrays (position in [`OpClass::ALL`]).
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Inverse of [`OpClass::index`].
    ///
    /// # Panics
    ///
    /// Panics if `i >= OpClass::COUNT`.
    #[inline]
    pub const fn from_index(i: usize) -> OpClass {
        Self::ALL[i]
    }

    /// The interchange format descriptor — the single source of truth for
    /// exponent/fraction widths (trace generation, tests and the schemes
    /// all read from here).
    pub const fn format(self) -> &'static FpFormat {
        match self {
            OpClass::Bf16 => &BF16,
            OpClass::Half => &HALF,
            OpClass::Single => &SINGLE,
            OpClass::Double => &DOUBLE,
            OpClass::Quad => &QUAD,
            OpClass::Fp256 => &FP256,
            OpClass::Fp512 => &FP512,
        }
    }

    /// True when the packed operand no longer fits the narrow `U128` word
    /// and must travel as `wideint::PackedBits` through the `_w` / wide
    /// batch entry points.
    pub const fn is_wide(self) -> bool {
        self.total_bits() > 128
    }

    /// Significand width including the hidden bit — the integer multiplier
    /// width handed to the block array (8 / 11 / 24 / 53 / 113 / 237 / 489).
    pub const fn sig_bits(self) -> u32 {
        self.format().sig_bits()
    }

    /// Total packed storage width (16 / 16 / 32 / 64 / 128 / 256 / 512).
    pub const fn total_bits(self) -> u32 {
        self.format().total_bits()
    }

    /// Display / CLI / metrics name.
    pub const fn name(self) -> &'static str {
        self.format().name
    }

    /// Parse from a CLI / config string (accepts the display name plus the
    /// IEEE interchange aliases, for every class).
    pub fn parse(s: &str) -> Option<OpClass> {
        match s {
            "bfloat16" => return Some(OpClass::Bf16),
            "binary16" | "fp16" => return Some(OpClass::Half),
            "binary32" | "fp32" => return Some(OpClass::Single),
            "binary64" | "fp64" => return Some(OpClass::Double),
            "binary128" | "fp128" => return Some(OpClass::Quad),
            "binary256" => return Some(OpClass::Fp256),
            "binary512" => return Some(OpClass::Fp512),
            _ => {}
        }
        Self::ALL.into_iter().find(|c| c.name() == s)
    }

    /// The class whose significand is exactly `width` bits, if any — how
    /// width-keyed caches route IEEE widths to the class plans.
    pub const fn from_sig_bits(width: u32) -> Option<OpClass> {
        match width {
            8 => Some(OpClass::Bf16),
            11 => Some(OpClass::Half),
            24 => Some(OpClass::Single),
            53 => Some(OpClass::Double),
            113 => Some(OpClass::Quad),
            237 => Some(OpClass::Fp256),
            489 => Some(OpClass::Fp512),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_dense_and_ordered_by_width() {
        let mut last = 0;
        for (i, class) in OpClass::ALL.into_iter().enumerate() {
            assert_eq!(class.index(), i);
            assert_eq!(OpClass::from_index(i), class);
            assert!(class.sig_bits() > last, "ALL must ascend by significand width");
            last = class.sig_bits();
            assert_eq!(OpClass::from_sig_bits(class.sig_bits()), Some(class));
        }
        assert_eq!(OpClass::from_sig_bits(48), None);
    }

    #[test]
    fn parse_roundtrip_and_aliases() {
        for class in OpClass::ALL {
            assert_eq!(OpClass::parse(class.name()), Some(class));
        }
        assert_eq!(OpClass::parse("binary16"), Some(OpClass::Half));
        assert_eq!(OpClass::parse("fp16"), Some(OpClass::Half));
        assert_eq!(OpClass::parse("bfloat16"), Some(OpClass::Bf16));
        assert_eq!(OpClass::parse("binary32"), Some(OpClass::Single));
        assert_eq!(OpClass::parse("fp64"), Some(OpClass::Double));
        assert_eq!(OpClass::parse("binary128"), Some(OpClass::Quad));
        assert_eq!(OpClass::parse("fp256"), Some(OpClass::Fp256));
        assert_eq!(OpClass::parse("binary256"), Some(OpClass::Fp256));
        assert_eq!(OpClass::parse("fp512"), Some(OpClass::Fp512));
        assert_eq!(OpClass::parse("binary512"), Some(OpClass::Fp512));
        assert_eq!(OpClass::parse("nope"), None);
    }

    #[test]
    fn formats_are_the_fpu_descriptors() {
        assert_eq!(OpClass::Single.format(), &SINGLE);
        assert_eq!(OpClass::Half.total_bits(), 16);
        assert_eq!(OpClass::Bf16.total_bits(), 16);
        assert_eq!(OpClass::Quad.sig_bits(), 113);
        // Wide classes outgrow U128; everything narrower still fits it.
        assert_eq!(OpClass::Fp256.total_bits(), 256);
        assert_eq!(OpClass::Fp512.total_bits(), 512);
        for class in OpClass::ALL {
            assert_eq!(class.is_wide(), class.total_bits() > 128, "{}", class.name());
        }
        assert!(!OpClass::Quad.is_wide());
        assert!(OpClass::Fp256.is_wide());
        // Class bitmasks across the stack fit one byte.
        assert!(OpClass::COUNT <= 8);
    }
}
