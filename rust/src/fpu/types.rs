//! Ergonomic typed wrappers over the generic bit-level pipeline.

use super::format::{FpClass, FpFormat, DOUBLE, QUAD, SINGLE};
use super::round::RoundMode;
use super::softfp::{mul_bits, DirectMul, Flags, SigMultiplier};
use crate::wideint::U128;

macro_rules! common_impl {
    ($ty:ident, $fmt:expr) => {
        impl $ty {
            /// The format descriptor for this type.
            pub const FORMAT: FpFormat = $fmt;

            /// Multiply with the default (direct) significand multiplier and
            /// round-to-nearest-even.
            pub fn mul(self, rhs: $ty) -> $ty {
                self.mul_with(rhs, RoundMode::NearestEven, &mut DirectMul).0
            }

            /// Multiply with an explicit rounding mode and significand
            /// multiplier backend, returning exception flags.
            pub fn mul_with(
                self,
                rhs: $ty,
                mode: RoundMode,
                m: &mut dyn SigMultiplier,
            ) -> ($ty, Flags) {
                let (bits, flags) = mul_bits(&Self::FORMAT, self.to_u128(), rhs.to_u128(), mode, m);
                ($ty::from_u128(bits), flags)
            }

            /// Classify the value.
            pub fn class(self) -> FpClass {
                Self::FORMAT.unpack(self.to_u128()).class
            }

            /// True if NaN.
            pub fn is_nan(self) -> bool {
                self.class() == FpClass::Nan
            }

            /// Sign bit.
            pub fn sign(self) -> bool {
                Self::FORMAT.unpack(self.to_u128()).sign
            }
        }
    };
}

/// IEEE binary32 value carried as raw bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp32(/** Raw IEEE binary32 bit pattern. */ pub u32);

impl Fp32 {
    /// From a native `f32`.
    pub fn from_f32(v: f32) -> Self {
        Fp32(v.to_bits())
    }
    /// To a native `f32`.
    pub fn to_f32(self) -> f32 {
        f32::from_bits(self.0)
    }
    fn to_u128(self) -> U128 {
        U128::from_u64(self.0 as u64)
    }
    fn from_u128(v: U128) -> Self {
        Fp32(v.as_u64() as u32)
    }
}
common_impl!(Fp32, SINGLE);

/// IEEE binary64 value carried as raw bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp64(/** Raw IEEE binary64 bit pattern. */ pub u64);

impl Fp64 {
    /// From a native `f64`.
    pub fn from_f64(v: f64) -> Self {
        Fp64(v.to_bits())
    }
    /// To a native `f64`.
    pub fn to_f64(self) -> f64 {
        f64::from_bits(self.0)
    }
    fn to_u128(self) -> U128 {
        U128::from_u64(self.0)
    }
    fn from_u128(v: U128) -> Self {
        Fp64(v.as_u64())
    }
}
common_impl!(Fp64, DOUBLE);

/// IEEE binary128 value carried as raw bits (no native Rust equivalent —
/// this *is* the quad substrate the paper's Fig. 3/4 path needs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp128(/** Raw IEEE binary128 bit pattern. */ pub u128);

impl Fp128 {
    /// Positive one.
    pub const ONE: Fp128 = Fp128(0x3FFF_0000_0000_0000_0000_0000_0000_0000);
    /// Positive two.
    pub const TWO: Fp128 = Fp128(0x4000_0000_0000_0000_0000_0000_0000_0000);

    /// Widen a native `f64` exactly into binary128 (every f64 is
    /// representable).
    pub fn from_f64(v: f64) -> Self {
        let bits = v.to_bits();
        let sign = (bits >> 63) as u128;
        let biased = ((bits >> 52) & 0x7FF) as i64;
        let frac = bits & 0x000F_FFFF_FFFF_FFFF;
        let out = if biased == 0x7FF {
            // Inf / NaN: shift payload into the quad fraction field.
            let qfrac = (frac as u128) << (112 - 52);
            (sign << 127) | (0x7FFFu128 << 112) | qfrac
        } else if biased == 0 {
            if frac == 0 {
                sign << 127
            } else {
                // f64 subnormal: value = frac * 2^(-1074); always a quad
                // normal. Normalize the 52-bit fraction.
                let lz = frac.leading_zeros() - 12; // leading zeros within 52 bits
                let shift = lz + 1;
                let nsig = (frac << shift) & 0x000F_FFFF_FFFF_FFFF; // drop hidden
                let e_unbiased = -1022 - shift as i64;
                let qbiased = (e_unbiased + 16383) as u128;
                (sign << 127) | (qbiased << 112) | ((nsig as u128) << 60)
            }
        } else {
            let e_unbiased = biased - 1023;
            let qbiased = (e_unbiased + 16383) as u128;
            (sign << 127) | (qbiased << 112) | ((frac as u128) << 60)
        };
        Fp128(out)
    }

    /// Truncate to a native `f64` with round-to-nearest-even (used only in
    /// examples/diagnostics; exactness is not guaranteed).
    pub fn to_f64_lossy(self) -> f64 {
        let u = QUAD.unpack(self.to_u128());
        match u.class {
            FpClass::Zero => {
                if u.sign {
                    -0.0
                } else {
                    0.0
                }
            }
            FpClass::Nan => f64::NAN,
            FpClass::Infinite => {
                if u.sign {
                    f64::NEG_INFINITY
                } else {
                    f64::INFINITY
                }
            }
            _ => {
                // Bit-level narrowing with RNE, including f64-subnormal
                // landing — exact packing, no powi (which underflows).
                let n = u.normalize(&QUAD);
                let mut e = n.exp; // value = sig / 2^112 * 2^e, sig in [2^112, 2^113)
                let mut shift = 113 - 53; // keep 53 bits
                if e < -1022 {
                    shift += (-1022 - e).min(200) as u32; // denormalize
                    e = -1022;
                }
                let kept = n.sig.shr(shift);
                let round = shift > 0 && n.sig.bit(shift - 1);
                let sticky = shift > 1 && n.sig.any_below(shift - 1);
                let mut mant = kept.as_u64();
                if round && (sticky || mant & 1 == 1) {
                    mant += 1;
                }
                if mant == 1u64 << 53 {
                    mant >>= 1;
                    e += 1;
                }
                let bits = if e > 1023 {
                    0x7FF0_0000_0000_0000u64 // overflow to +inf
                } else if mant >= 1u64 << 52 {
                    // normal
                    (((e + 1023) as u64) << 52) | (mant & 0x000F_FFFF_FFFF_FFFF)
                } else {
                    // subnormal (e == -1022 here) or zero
                    mant
                };
                let mag = f64::from_bits(bits);
                if u.sign {
                    -mag
                } else {
                    mag
                }
            }
        }
    }

    fn to_u128(self) -> U128 {
        U128::from_u128(self.0)
    }
    fn from_u128(v: U128) -> Self {
        Fp128(v.as_u128())
    }
}
common_impl!(Fp128, QUAD);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp128_from_f64_exact_small_ints() {
        for v in [0.0, 1.0, -1.0, 2.0, 0.5, 3.25, -1024.0] {
            let q = Fp128::from_f64(v);
            assert_eq!(q.to_f64_lossy(), v, "roundtrip {v}");
        }
        assert_eq!(Fp128::from_f64(1.0), Fp128::ONE);
        assert_eq!(Fp128::from_f64(2.0), Fp128::TWO);
    }

    #[test]
    fn fp128_from_f64_specials() {
        assert!(Fp128::from_f64(f64::NAN).is_nan());
        assert_eq!(Fp128::from_f64(f64::INFINITY).class(), FpClass::Infinite);
        assert_eq!(Fp128::from_f64(-0.0).class(), FpClass::Zero);
        assert!(Fp128::from_f64(-0.0).sign());
    }

    #[test]
    fn fp128_from_f64_subnormal() {
        let tiny = f64::from_bits(1); // smallest positive subnormal
        let q = Fp128::from_f64(tiny);
        assert_eq!(q.class(), FpClass::Normal); // quad-normal
        assert_eq!(q.to_f64_lossy(), tiny);
        let mid = f64::from_bits(0x000F_0000_0000_0001);
        assert_eq!(Fp128::from_f64(mid).to_f64_lossy(), mid);
    }

    #[test]
    fn fp128_roundtrip_extremes() {
        for v in [f64::MAX, f64::MIN_POSITIVE, 1e-300, 1e300] {
            assert_eq!(Fp128::from_f64(v).to_f64_lossy(), v);
        }
    }
}
