//! Ergonomic typed wrappers over the generic bit-level pipeline.

use super::format::{FpClass, FpFormat, BF16, DOUBLE, HALF, QUAD, SINGLE};
use super::round::RoundMode;
use super::softfp::{mul_bits, DirectMul, Flags, SigMultiplier};
use crate::wideint::U128;

macro_rules! common_impl {
    ($ty:ident, $fmt:expr) => {
        impl $ty {
            /// The format descriptor for this type.
            pub const FORMAT: FpFormat = $fmt;

            /// Multiply with the default (direct) significand multiplier and
            /// round-to-nearest-even.
            pub fn mul(self, rhs: $ty) -> $ty {
                self.mul_with(rhs, RoundMode::NearestEven, &mut DirectMul).0
            }

            /// Multiply with an explicit rounding mode and significand
            /// multiplier backend, returning exception flags.
            pub fn mul_with(
                self,
                rhs: $ty,
                mode: RoundMode,
                m: &mut dyn SigMultiplier,
            ) -> ($ty, Flags) {
                let (bits, flags) = mul_bits(&Self::FORMAT, self.to_u128(), rhs.to_u128(), mode, m);
                ($ty::from_u128(bits), flags)
            }

            /// Classify the value.
            pub fn class(self) -> FpClass {
                Self::FORMAT.unpack(self.to_u128()).class
            }

            /// True if NaN.
            pub fn is_nan(self) -> bool {
                self.class() == FpClass::Nan
            }

            /// Sign bit.
            pub fn sign(self) -> bool {
                Self::FORMAT.unpack(self.to_u128()).sign
            }
        }
    };
}

/// IEEE binary16 ("half") value carried as raw bits — the first sub-single
/// class the open op-class registry serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp16(/** Raw IEEE binary16 bit pattern. */ pub u16);

impl Fp16 {
    /// Convert from a native `f32` with round-to-nearest-even (the IEEE
    /// `convertFormat` operation, subnormals and overflow included).
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        let sign = ((bits >> 31) as u16) << 15;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let frac = bits & 0x007F_FFFF;
        if exp == 0xFF {
            // Inf stays Inf; NaN canonicalizes to a quiet NaN.
            return Fp16(if frac == 0 { sign | 0x7C00 } else { sign | 0x7E00 });
        }
        if exp == 0 {
            // f32 subnormals are < 2^-126, far below half's 2^-24 ulp: they
            // round to signed zero under RNE.
            return Fp16(sign);
        }
        // Normal f32: 24-bit significand with the hidden bit at 23.
        let sig = frac | 0x0080_0000;
        let mut e = exp - 127; // unbiased
        // Keep 11 bits: shift right by 13, more if the result denormalizes.
        let mut shift = 13u32;
        if e < -14 {
            shift += ((-14 - e) as u32).min(32);
            e = -14;
        }
        let (kept, round, sticky) = if shift >= 32 {
            (0u32, false, sig != 0)
        } else {
            (
                sig >> shift,
                (sig >> (shift - 1)) & 1 == 1,
                sig & ((1 << (shift - 1)) - 1) != 0,
            )
        };
        let mut kept = kept;
        if round && (sticky || kept & 1 == 1) {
            kept += 1; // RNE; may carry into the exponent
        }
        if kept >= 1 << 11 {
            kept >>= 1;
            e += 1;
        }
        if kept >= 1 << 10 {
            // Normal (the carry above may have renormalized a subnormal).
            if e > 15 {
                return Fp16(sign | 0x7C00); // overflow to inf (RNE)
            }
            Fp16(sign | (((e + 15) as u16) << 10) | (kept as u16 & 0x03FF))
        } else {
            // Subnormal or zero (e == -14 here).
            Fp16(sign | kept as u16)
        }
    }

    /// Widen exactly to a native `f32` (every binary16 is representable).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 >> 15) as u32) << 31;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let frac = (self.0 & 0x03FF) as u32;
        let bits = if exp == 0x1F {
            // Inf / NaN: payload shifts into the f32 fraction field.
            sign | 0x7F80_0000 | (frac << 13)
        } else if exp == 0 {
            if frac == 0 {
                sign
            } else {
                // Subnormal: value = frac * 2^-24; normalize into f32.
                let lz = frac.leading_zeros() - 22; // zeros within 10 bits
                let nfrac = (frac << (lz + 1)) & 0x03FF; // drop hidden
                let e = -14 - (lz as i32 + 1) + 127;
                sign | ((e as u32) << 23) | (nfrac << 13)
            }
        } else {
            sign | ((exp + 127 - 15) << 23) | (frac << 13)
        };
        f32::from_bits(bits)
    }

    fn to_u128(self) -> U128 {
        U128::from_u64(self.0 as u64)
    }
    fn from_u128(v: U128) -> Self {
        Fp16(v.as_u64() as u16)
    }
}
common_impl!(Fp16, HALF);

/// bfloat16 value carried as raw bits — the truncated-single ML format,
/// the second sub-single class the registry serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Bf16(/** Raw bfloat16 bit pattern. */ pub u16);

impl Bf16 {
    /// Convert from a native `f32` with round-to-nearest-even. bfloat16
    /// shares binary32's exponent range, so this is rounding the low 16
    /// fraction bits off (a fraction carry correctly ripples into the
    /// exponent, max-finite rounding up to infinity included).
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        if v.is_nan() {
            return Bf16((((bits >> 31) as u16) << 15) | 0x7FC0);
        }
        let kept = bits >> 16;
        let round = (bits >> 15) & 1 == 1;
        let sticky = bits & 0x7FFF != 0;
        let inc = round && (sticky || kept & 1 == 1);
        Bf16((kept + inc as u32) as u16)
    }

    /// Widen exactly to a native `f32` (bit pattern `<< 16`).
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    fn to_u128(self) -> U128 {
        U128::from_u64(self.0 as u64)
    }
    fn from_u128(v: U128) -> Self {
        Bf16(v.as_u64() as u16)
    }
}
common_impl!(Bf16, BF16);

/// IEEE binary32 value carried as raw bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp32(/** Raw IEEE binary32 bit pattern. */ pub u32);

impl Fp32 {
    /// From a native `f32`.
    pub fn from_f32(v: f32) -> Self {
        Fp32(v.to_bits())
    }
    /// To a native `f32`.
    pub fn to_f32(self) -> f32 {
        f32::from_bits(self.0)
    }
    fn to_u128(self) -> U128 {
        U128::from_u64(self.0 as u64)
    }
    fn from_u128(v: U128) -> Self {
        Fp32(v.as_u64() as u32)
    }
}
common_impl!(Fp32, SINGLE);

/// IEEE binary64 value carried as raw bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp64(/** Raw IEEE binary64 bit pattern. */ pub u64);

impl Fp64 {
    /// From a native `f64`.
    pub fn from_f64(v: f64) -> Self {
        Fp64(v.to_bits())
    }
    /// To a native `f64`.
    pub fn to_f64(self) -> f64 {
        f64::from_bits(self.0)
    }
    fn to_u128(self) -> U128 {
        U128::from_u64(self.0)
    }
    fn from_u128(v: U128) -> Self {
        Fp64(v.as_u64())
    }
}
common_impl!(Fp64, DOUBLE);

/// IEEE binary128 value carried as raw bits (no native Rust equivalent —
/// this *is* the quad substrate the paper's Fig. 3/4 path needs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp128(/** Raw IEEE binary128 bit pattern. */ pub u128);

impl Fp128 {
    /// Positive one.
    pub const ONE: Fp128 = Fp128(0x3FFF_0000_0000_0000_0000_0000_0000_0000);
    /// Positive two.
    pub const TWO: Fp128 = Fp128(0x4000_0000_0000_0000_0000_0000_0000_0000);

    /// Widen a native `f64` exactly into binary128 (every f64 is
    /// representable).
    pub fn from_f64(v: f64) -> Self {
        let bits = v.to_bits();
        let sign = (bits >> 63) as u128;
        let biased = ((bits >> 52) & 0x7FF) as i64;
        let frac = bits & 0x000F_FFFF_FFFF_FFFF;
        let out = if biased == 0x7FF {
            // Inf / NaN: shift payload into the quad fraction field.
            let qfrac = (frac as u128) << (112 - 52);
            (sign << 127) | (0x7FFFu128 << 112) | qfrac
        } else if biased == 0 {
            if frac == 0 {
                sign << 127
            } else {
                // f64 subnormal: value = frac * 2^(-1074); always a quad
                // normal. Normalize the 52-bit fraction.
                let lz = frac.leading_zeros() - 12; // leading zeros within 52 bits
                let shift = lz + 1;
                let nsig = (frac << shift) & 0x000F_FFFF_FFFF_FFFF; // drop hidden
                let e_unbiased = -1022 - shift as i64;
                let qbiased = (e_unbiased + 16383) as u128;
                (sign << 127) | (qbiased << 112) | ((nsig as u128) << 60)
            }
        } else {
            let e_unbiased = biased - 1023;
            let qbiased = (e_unbiased + 16383) as u128;
            (sign << 127) | (qbiased << 112) | ((frac as u128) << 60)
        };
        Fp128(out)
    }

    /// Truncate to a native `f64` with round-to-nearest-even (used only in
    /// examples/diagnostics; exactness is not guaranteed).
    pub fn to_f64_lossy(self) -> f64 {
        let u = QUAD.unpack(self.to_u128());
        match u.class {
            FpClass::Zero => {
                if u.sign {
                    -0.0
                } else {
                    0.0
                }
            }
            FpClass::Nan => f64::NAN,
            FpClass::Infinite => {
                if u.sign {
                    f64::NEG_INFINITY
                } else {
                    f64::INFINITY
                }
            }
            _ => {
                // Bit-level narrowing with RNE, including f64-subnormal
                // landing — exact packing, no powi (which underflows).
                let n = u.normalize(&QUAD);
                let mut e = n.exp; // value = sig / 2^112 * 2^e, sig in [2^112, 2^113)
                let mut shift = 113 - 53; // keep 53 bits
                if e < -1022 {
                    shift += (-1022 - e).min(200) as u32; // denormalize
                    e = -1022;
                }
                let kept = n.sig.shr(shift);
                let round = shift > 0 && n.sig.bit(shift - 1);
                let sticky = shift > 1 && n.sig.any_below(shift - 1);
                let mut mant = kept.as_u64();
                if round && (sticky || mant & 1 == 1) {
                    mant += 1;
                }
                if mant == 1u64 << 53 {
                    mant >>= 1;
                    e += 1;
                }
                let bits = if e > 1023 {
                    0x7FF0_0000_0000_0000u64 // overflow to +inf
                } else if mant >= 1u64 << 52 {
                    // normal
                    (((e + 1023) as u64) << 52) | (mant & 0x000F_FFFF_FFFF_FFFF)
                } else {
                    // subnormal (e == -1022 here) or zero
                    mant
                };
                let mag = f64::from_bits(bits);
                if u.sign {
                    -mag
                } else {
                    mag
                }
            }
        }
    }

    fn to_u128(self) -> U128 {
        U128::from_u128(self.0)
    }
    fn from_u128(v: U128) -> Self {
        Fp128(v.as_u128())
    }
}
common_impl!(Fp128, QUAD);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp128_from_f64_exact_small_ints() {
        for v in [0.0, 1.0, -1.0, 2.0, 0.5, 3.25, -1024.0] {
            let q = Fp128::from_f64(v);
            assert_eq!(q.to_f64_lossy(), v, "roundtrip {v}");
        }
        assert_eq!(Fp128::from_f64(1.0), Fp128::ONE);
        assert_eq!(Fp128::from_f64(2.0), Fp128::TWO);
    }

    #[test]
    fn fp128_from_f64_specials() {
        assert!(Fp128::from_f64(f64::NAN).is_nan());
        assert_eq!(Fp128::from_f64(f64::INFINITY).class(), FpClass::Infinite);
        assert_eq!(Fp128::from_f64(-0.0).class(), FpClass::Zero);
        assert!(Fp128::from_f64(-0.0).sign());
    }

    #[test]
    fn fp128_from_f64_subnormal() {
        let tiny = f64::from_bits(1); // smallest positive subnormal
        let q = Fp128::from_f64(tiny);
        assert_eq!(q.class(), FpClass::Normal); // quad-normal
        assert_eq!(q.to_f64_lossy(), tiny);
        let mid = f64::from_bits(0x000F_0000_0000_0001);
        assert_eq!(Fp128::from_f64(mid).to_f64_lossy(), mid);
    }

    #[test]
    fn fp128_roundtrip_extremes() {
        for v in [f64::MAX, f64::MIN_POSITIVE, 1e-300, 1e300] {
            assert_eq!(Fp128::from_f64(v).to_f64_lossy(), v);
        }
    }

    #[test]
    fn fp16_roundtrip_exhaustive() {
        // to_f32 is exact, so from_f32 ∘ to_f32 must be the identity on
        // every non-NaN binary16 pattern — all 65536 checked.
        for bits in 0..=u16::MAX {
            let h = Fp16(bits);
            if h.is_nan() {
                assert!(h.to_f32().is_nan());
                assert!(Fp16::from_f32(h.to_f32()).is_nan());
                continue;
            }
            assert_eq!(Fp16::from_f32(h.to_f32()).0, bits, "{bits:#06x}");
        }
    }

    #[test]
    fn bf16_roundtrip_exhaustive() {
        for bits in 0..=u16::MAX {
            let b = Bf16(bits);
            if b.is_nan() {
                assert!(b.to_f32().is_nan());
                assert!(Bf16::from_f32(b.to_f32()).is_nan());
                continue;
            }
            assert_eq!(Bf16::from_f32(b.to_f32()).0, bits, "{bits:#06x}");
        }
    }

    #[test]
    fn fp16_from_f32_directed() {
        assert_eq!(Fp16::from_f32(1.0).0, 0x3C00);
        assert_eq!(Fp16::from_f32(-2.0).0, 0xC000);
        assert_eq!(Fp16::from_f32(65504.0).0, 0x7BFF); // max finite
        assert_eq!(Fp16::from_f32(65520.0).0, 0x7C00); // rounds to inf
        assert_eq!(Fp16::from_f32(f32::INFINITY).0, 0x7C00);
        assert!(Fp16::from_f32(f32::NAN).is_nan());
        // min subnormal 2^-24; half of it ties to even (zero).
        assert_eq!(Fp16::from_f32(5.9604645e-8).0, 0x0001);
        assert_eq!(Fp16::from_f32(2.9802322e-8).0, 0x0000);
        assert_eq!(Fp16::from_f32(-0.0).0, 0x8000);
        // f32 subnormals collapse to signed zero.
        assert_eq!(Fp16::from_f32(f32::from_bits(1)).0, 0x0000);
    }

    #[test]
    fn bf16_from_f32_directed() {
        assert_eq!(Bf16::from_f32(1.0).0, 0x3F80);
        assert_eq!(Bf16::from_f32(-1.5).0, 0xBFC0);
        assert_eq!(Bf16::from_f32(f32::MAX).0, 0x7F80); // rounds to inf
        assert_eq!(Bf16::from_f32(f32::INFINITY).0, 0x7F80);
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        // RNE on the dropped 16 bits: 1 + 2^-8 is a tie -> stays even.
        assert_eq!(Bf16::from_f32(f32::from_bits(0x3F80_8000)).0, 0x3F80);
        assert_eq!(Bf16::from_f32(f32::from_bits(0x3F80_8001)).0, 0x3F81);
    }

    #[test]
    fn fp16_mul_matches_f32_reference() {
        // An 11x11-bit product is exact in f32 and the exponent range
        // fits, so f32 multiply + one RNE narrowing is the correctly
        // rounded binary16 product — a hardware-backed oracle.
        let mut rng = crate::proput::Rng::new(0x16A);
        for _ in 0..20_000 {
            let a = Fp16(rng.next_u64() as u16);
            let b = Fp16(rng.next_u64() as u16);
            let got = a.mul(b);
            let want = Fp16::from_f32(a.to_f32() * b.to_f32());
            if want.is_nan() {
                assert!(got.is_nan(), "a={:#06x} b={:#06x}", a.0, b.0);
            } else {
                assert_eq!(got.0, want.0, "a={:#06x} b={:#06x}", a.0, b.0);
            }
        }
    }
}
