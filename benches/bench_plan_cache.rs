//! §Perf — compiled tile plans vs per-call tile-DAG derivation.
//!
//! The paper's tile wiring is static hardware; re-deriving the tile DAG on
//! every multiplication measures the *planner*, not the architecture. This
//! bench quantifies the gap on the raw significand product for SP / DP /
//! QP under every organization, and on the coordinator's batch path.
//!
//! Three executors per (scheme, precision):
//! * `rederive` — `decomp::execute`: walks the chunk lists and allocates
//!   the tile vector per call (the seed hot path);
//! * `plan`     — `PlanCache` + `Plan::execute`: flat pre-resolved steps,
//!   O(1) stats merge, zero allocation;
//! * `direct`   — the plain widening multiply (lower bound, no
//!   decomposition at all).
//!
//! Also covers the batch surfaces (`Plan::execute_batch` with its single
//! scaled stats merge, `NativeBackend::mul_batch`) and writes every
//! measurement to `BENCH_plan.json` at the repo root (README
//! "Benchmarks"). `CIVP_BENCH_QUICK=1` shrinks iteration counts for CI.

use civp::benchx::{bb, bench, scaled, section, JsonReport};
use civp::coordinator::NativeBackend;
use civp::decomp::{execute, ExecStats, OpClass, PlanCache, Scheme, SchemeKind};
use civp::fpu::{mul_bits, DirectMul, RoundMode};
use civp::proput::Rng;
use civp::wideint::{mul_u128, PackedBits, U128, U256};


fn main() {
    // The full registry's U128-path classes (sub-single included). The wide
    // classes run the tree path; `bench_formats` carries their ablation.
    let precisions: Vec<OpClass> = OpClass::ALL.into_iter().filter(|c| !c.is_wide()).collect();
    let kinds = SchemeKind::ALL; // civp + all three baselines
    let mut json = JsonReport::new();
    let iters = scaled(10_000);

    section("significand product: cached plan vs per-call tile-DAG derivation");
    let mut verdicts: Vec<(String, f64)> = Vec::new();
    for &prec in &precisions {
        for kind in kinds {
            let bits = prec.sig_bits();
            let scheme = Scheme::new(kind, prec);
            let plan = PlanCache::get(kind, prec);
            let mut rng = Rng::new(0xBEEF ^ bits as u64);
            let pairs: Vec<(U128, U128)> =
                (0..256).map(|_| (rng.sig(bits), rng.sig(bits))).collect();
            // correctness cross-check before timing (via the batch surface)
            let mut st = ExecStats::default();
            let (av, bv): (Vec<U128>, Vec<U128>) = pairs.iter().copied().unzip();
            let mut products = Vec::new();
            plan.execute_batch(&av, &bv, &mut st, &mut products);
            assert_eq!(st.muls, 256, "batch stats must account every element");
            for (i, &(a, b)) in pairs.iter().enumerate() {
                assert_eq!(products[i], mul_u128(a, b));
            }

            let label = format!("{}-{}", kind.name(), prec.name());
            let mut i = 0usize;
            let mut stats = ExecStats::default();
            let rederive = bench(&format!("{label:<16} rederive/call"), 2_000, 30, iters, || {
                let (a, b) = pairs[i & 255];
                i += 1;
                bb(execute(&scheme, a, b, &mut stats));
            });
            let mut i = 0usize;
            let mut stats = ExecStats::default();
            let planned = bench(&format!("{label:<16} cached plan"), 2_000, 30, iters, || {
                let (a, b) = pairs[i & 255];
                i += 1;
                bb(plan.execute(a, b, &mut stats));
            });
            let mut i = 0usize;
            bench(&format!("{label:<16} direct (oracle)"), 2_000, 30, iters, || {
                let (a, b) = pairs[i & 255];
                i += 1;
                bb(mul_u128(a, b));
            });
            json.push(&format!("plan/{label}/rederive-per-call"), rederive);
            json.push(&format!("plan/{label}/cached-plan"), planned);
            verdicts.push((label, rederive.ns_per_op_p50 / planned.ns_per_op_p50));
        }
    }

    section("plan batch surface: execute_batch (one scaled stats merge per batch)");
    for &prec in &precisions {
        let bits = prec.sig_bits();
        let plan = PlanCache::get(SchemeKind::Civp, prec);
        let mut rng = Rng::new(0xD00D ^ bits as u64);
        let a: Vec<U128> = (0..256).map(|_| rng.sig(bits)).collect();
        let b: Vec<U128> = (0..256).map(|_| rng.sig(bits)).collect();
        let mut stats = ExecStats::default();
        let mut out: Vec<U256> = Vec::with_capacity(256);
        let batch = bench(
            &format!("civp-{:<8} execute_batch x256", prec.name()),
            20,
            20,
            scaled(200).max(2),
            || {
                plan.execute_batch(&a, &b, &mut stats, &mut out);
                bb(out.len());
            },
        );
        json.push(&format!("plan/civp-{}/execute-batch-x256", prec.name()), batch);
    }

    section("coordinator batch path: mul_batch (reused scratch) vs per-call pipeline");
    for &prec in &precisions {
        let fmt = prec.format();
        let bits = fmt.total_bits();
        let mut rng = Rng::new(0xABCD ^ bits as u64);
        let mask = if bits == 128 { u128::MAX } else { (1u128 << bits) - 1 };
        let a: Vec<PackedBits> = (0..256)
            .map(|_| {
                let v = (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask;
                PackedBits::from_u128(v)
            })
            .collect();
        let b: Vec<PackedBits> = (0..256)
            .map(|_| {
                let v = (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask;
                PackedBits::from_u128(v)
            })
            .collect();

        let mut be = NativeBackend::new(SchemeKind::Civp);
        let mut out = Vec::with_capacity(a.len());
        let m = bench(&format!("{:<8} mul_batch x256", prec.name()), 20, 20, scaled(50).max(2), || {
            be.mul_batch(prec, &a, &b, &mut out).unwrap();
            bb(out.len());
        });
        json.push(&format!("coordinator/{}/mul-batch-x256", prec.name()), m);
        let mut dm = DirectMul;
        bench(&format!("{:<8} per-call direct x256", prec.name()), 20, 20, scaled(50).max(2), || {
            let mut fresh: Vec<u128> = Vec::with_capacity(a.len());
            for i in 0..a.len() {
                let (bits, _) = mul_bits(
                    fmt,
                    U128::from_u128(a[i].as_u128()),
                    U128::from_u128(b[i].as_u128()),
                    RoundMode::NearestEven,
                    &mut dm,
                );
                fresh.push(bits.as_u128());
            }
            bb(fresh.len());
        });
    }

    section("verdict: cached plan speedup over per-call derivation (p50)");
    let mut all_faster = true;
    for (label, speedup) in &verdicts {
        let verdict = if *speedup > 1.0 { "faster" } else { "SLOWER" };
        println!("{label:<20} {speedup:>6.2}x {verdict}");
        all_faster &= *speedup > 1.0;
    }
    println!(
        "\n{}",
        if all_faster {
            "PASS: cached-plan execution beats tile-DAG re-derivation on every scheme x precision"
        } else {
            "FAIL: at least one configuration did not benefit from plan caching"
        }
    );

    json.write("BENCH_plan.json").expect("write BENCH_plan.json");
}
