//! E4 + E5 — Fig. 4 and §II.C: quadruple-precision 114x114 multiplication
//! and the wasted-computation claim.
//!
//! Regenerates: the 36-block CIVP inventory (16 + 16 + 4), the 49-block
//! 18x18 baseline, the paper's claimed 17/49 (35%) wastage vs the
//! recomputed 13/49 (26.5%), and the energy-per-op comparison that is the
//! paper's "low power" headline. Then measures the software pipeline.

use civp::benchx::{bb, bench, section};
use civp::decomp::analysis::{PAPER_CLAIMED_QP_TOTAL_18X18, PAPER_CLAIMED_QP_WASTED_18X18};
use civp::decomp::{scheme_census, BlockKind, DecompMul, OpClass, Scheme, SchemeKind};
use civp::fabric::{schedule_op, CostModel, FabricConfig};
use civp::fpu::{Fp128, RoundMode};
use civp::proput::Rng;

fn main() {
    section("E4 static: Fig. 4 — 114x114 quad partitioning");
    let civp = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Quad));
    println!(
        "civp-quad: padded {} bits, {} blocks = {} x24x24 + {} x24x9 + {} x9x9",
        civp.padded_bits,
        civp.total_blocks,
        civp.count(BlockKind::M24x24),
        civp.count(BlockKind::M24x9),
        civp.count(BlockKind::M9x9),
    );
    assert_eq!(civp.total_blocks, 36);

    let b18 = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Quad));
    println!(
        "18x18-quad: padded {} bits, {} blocks ({} padded)",
        b18.padded_bits, b18.total_blocks, b18.padded_blocks
    );
    assert_eq!(b18.total_blocks, PAPER_CLAIMED_QP_TOTAL_18X18);

    section("E5: §II.C wasted-computation claim");
    println!(
        "paper claim : {}/{} blocks wasted = {:.1}%",
        PAPER_CLAIMED_QP_WASTED_18X18,
        PAPER_CLAIMED_QP_TOTAL_18X18,
        PAPER_CLAIMED_QP_WASTED_18X18 as f64 / PAPER_CLAIMED_QP_TOTAL_18X18 as f64 * 100.0
    );
    println!(
        "recomputed  : {}/{} blocks padded = {:.1}%   (113 = 6*18+5 -> 7+7-1 tiles touch the 5-bit chunk)",
        b18.padded_blocks,
        b18.total_blocks,
        b18.padded_fraction() * 100.0
    );
    println!(
        "civp        : {}/{} blocks padded = {:.1}%   (113 -> 114 pads a single bit,\n\
         \u{20}             which grazes every tile touching the top 9-bit chunk — but wastes\n\
         \u{20}             almost no *computation*; the bit-level metric below is the fair one)",
        civp.padded_blocks,
        civp.total_blocks,
        civp.padded_fraction() * 100.0
    );
    println!(
        "bit-level utilization: civp {:.1}% vs 18x18 {:.1}% — wasted array capacity {:.1}x lower under civp",
        civp.utilization * 100.0,
        b18.utilization * 100.0,
        (1.0 - b18.utilization) / (1.0 - civp.utilization)
    );

    section("E4 energy: one quad multiply (dyn energy, useful fraction)");
    let cost = CostModel::default();
    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>9} {:>8}",
        "scheme", "blocks", "energy", "useful-E", "wasted%", "lat"
    );
    for kind in SchemeKind::ALL {
        let scheme = Scheme::new(kind, OpClass::Quad);
        let fabric = match kind {
            SchemeKind::Civp => FabricConfig::civp_default(),
            _ => FabricConfig::legacy_default(),
        };
        let s = schedule_op(&scheme, &fabric, &cost);
        println!(
            "{:<10} {:>8} {:>10.3} {:>10.3} {:>9.1} {:>8}",
            kind.name(),
            scheme.block_count(),
            s.dyn_energy,
            s.useful_energy,
            (1.0 - s.useful_energy / s.dyn_energy) * 100.0,
            s.latency_cycles
        );
    }

    section("E4 measured: software IEEE fp128 pipeline throughput per scheme");
    let mut rng = Rng::new(0xE4);
    let pairs: Vec<(Fp128, Fp128)> = (0..1024)
        .map(|_| {
            (
                Fp128::from_f64(f64::from_bits(rng.nasty_bits64())),
                Fp128::from_f64(f64::from_bits(rng.nasty_bits64())),
            )
        })
        .collect();
    for kind in SchemeKind::ALL {
        let mut m = DecompMul::new(kind);
        let mut i = 0;
        bench(&format!("fp128 mul via {}", kind.name()), 1_000, 30, 10_000, || {
            let (a, b) = pairs[i & 1023];
            i += 1;
            bb(a.mul_with(b, RoundMode::NearestEven, &mut m));
        });
    }
    let mut direct = civp::fpu::DirectMul;
    let mut i = 0;
    bench("fp128 mul via direct (no decomposition)", 1_000, 30, 10_000, || {
        let (a, b) = pairs[i & 1023];
        i += 1;
        bb(a.mul_with(b, RoundMode::NearestEven, &mut direct));
    });
}
