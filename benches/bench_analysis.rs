//! E6 — §III: the full unified analysis table.
//!
//! For every (precision, organization) pair: block inventory, padded
//! blocks, utilization, per-op dynamic energy / useful energy / latency,
//! and pipelined throughput on the default fabric sizing — the quantified
//! version of the paper's qualitative §III table, plus the iso-area
//! comparison the paper implies ("replace" = same silicon budget).

use civp::benchx::section;
use civp::decomp::{AnalysisRow, OpClass, Scheme, SchemeKind};
use civp::fabric::{schedule_op, simulate_stream, CostModel, FabricConfig, FabricOp};

fn main() {
    let cost = CostModel::default();

    section("E6a: blocks / utilization (static census)");
    println!(
        "{:<10} {:<8} {:>7} {:>8} {:>8} | {:>6} {:>6} {:>6} {:>6} {:>6}",
        "precision", "scheme", "blocks", "padded", "util%", "24x24", "24x9", "9x9", "18x18", "25x18"
    );
    for row in AnalysisRow::full_table() {
        let c = &row.census;
        println!(
            "{:<10} {:<8} {:>7} {:>8} {:>8.1} | {:>6} {:>6} {:>6} {:>6} {:>6}",
            row.class.name(),
            row.kind.name(),
            c.total_blocks,
            c.padded_blocks,
            c.utilization * 100.0,
            c.count(civp::decomp::BlockKind::M24x24),
            c.count(civp::decomp::BlockKind::M24x9),
            c.count(civp::decomp::BlockKind::M9x9),
            c.count(civp::decomp::BlockKind::M18x18),
            c.count(civp::decomp::BlockKind::M25x18),
        );
    }

    section("E6b: per-op cost on the default fabrics");
    println!(
        "{:<10} {:<8} {:>10} {:>10} {:>9} {:>6} {:>5}",
        "precision", "scheme", "energy", "useful-E", "wasted%", "lat", "II"
    );
    for prec in OpClass::ALL {
        for kind in SchemeKind::ALL {
            let scheme = Scheme::new(kind, prec);
            let fabric = match kind {
                SchemeKind::Civp => FabricConfig::civp_default(),
                _ => FabricConfig::legacy_default(),
            };
            let s = schedule_op(&scheme, &fabric, &cost);
            println!(
                "{:<10} {:<8} {:>10.3} {:>10.3} {:>9.1} {:>6} {:>5}",
                prec.name(),
                kind.name(),
                s.dyn_energy,
                s.useful_energy,
                (1.0 - s.useful_energy / s.dyn_energy) * 100.0,
                s.latency_cycles,
                s.initiation_interval
            );
        }
    }

    section("E6c: iso-area streaming comparison (the paper's 'replace' semantics)");
    // Same silicon: CIVP column vs 40x 18x18 blocks. Stream 10k ops of each
    // precision and compare cycles + energy.
    let civp_fabric = FabricConfig::civp_scaled(1);
    let iso_fabric = FabricConfig::legacy_iso_area(1);
    println!(
        "fabric areas: civp={:.1} (18x18-equivalents), legacy-iso={:.1}",
        civp_fabric.total_area(),
        iso_fabric.total_area()
    );
    println!(
        "\n{:<10} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "precision", "civp cyc", "iso18 cyc", "civp E/op", "iso18 E/op", "civp wst%", "iso wst%"
    );
    for prec in OpClass::ALL {
        let n = 10_000;
        let civp_ops: Vec<FabricOp> =
            vec![FabricOp { class: prec, organization: SchemeKind::Civp }; n];
        let b18_ops: Vec<FabricOp> =
            vec![FabricOp { class: prec, organization: SchemeKind::Baseline18 }; n];
        let rc = simulate_stream(&civp_ops, &civp_fabric, &cost);
        let rb = simulate_stream(&b18_ops, &iso_fabric, &cost);
        println!(
            "{:<10} {:>12} {:>12} {:>12.3} {:>12.3} {:>10.1} {:>10.1}",
            prec.name(),
            rc.cycles,
            rb.cycles,
            rc.energy_per_op(),
            rb.energy_per_op(),
            rc.wasted_fraction() * 100.0,
            rb.wasted_fraction() * 100.0
        );
    }
    println!("\n(lower energy/op + lower wasted% at SP/QP is the paper's §III conclusion)");
}
