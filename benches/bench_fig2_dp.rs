//! E3 — Fig. 2: double-precision 57x57 partitioning.
//!
//! Regenerates Fig. 2(b)'s block inventory (four 24x24 + four 24x9 + one
//! 9x9), compares against the nine-18x18 alternative the paper concedes in
//! §II.B, and measures the software pipeline under both.

use civp::benchx::{bb, bench, section};
use civp::decomp::{scheme_census, BlockKind, DecompMul, OpClass, Scheme, SchemeKind};
use civp::fabric::{schedule_op, CostModel, FabricConfig};
use civp::fpu::{Fp64, RoundMode};
use civp::proput::Rng;

fn main() {
    section("E3 static: Fig. 2(b) — 57x57 double-precision partitioning");
    let civp = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Double));
    println!(
        "civp-double: padded {} bits, {} blocks = {} x24x24 + {} x24x9 + {} x9x9",
        civp.padded_bits,
        civp.total_blocks,
        civp.count(BlockKind::M24x24),
        civp.count(BlockKind::M24x9),
        civp.count(BlockKind::M9x9),
    );
    assert_eq!(
        (civp.count(BlockKind::M24x24), civp.count(BlockKind::M24x9), civp.count(BlockKind::M9x9)),
        (4, 4, 1),
        "Fig. 2(b) block inventory"
    );

    println!(
        "\n{:<10} {:>7} {:>8} {:>8} {:>10} {:>10} {:>8}",
        "scheme", "blocks", "padded", "util%", "energy", "useful-E", "lat"
    );
    let cost = CostModel::default();
    for kind in SchemeKind::ALL {
        let scheme = Scheme::new(kind, OpClass::Double);
        let census = scheme_census(&scheme);
        let fabric = match kind {
            SchemeKind::Civp => FabricConfig::civp_default(),
            _ => FabricConfig::legacy_default(),
        };
        let sched = schedule_op(&scheme, &fabric, &cost);
        println!(
            "{:<10} {:>7} {:>8} {:>8.1} {:>10.3} {:>10.3} {:>8}",
            kind.name(),
            census.total_blocks,
            census.padded_blocks,
            census.utilization * 100.0,
            sched.dyn_energy,
            sched.useful_energy,
            sched.latency_cycles
        );
    }
    println!(
        "\npaper §II.B concession reproduced: 18x18 also needs 9 blocks for DP;\n\
         CIVP's advantage at DP is unification, not count."
    );

    section("E3 measured: software IEEE fp64 pipeline throughput per scheme");
    let mut rng = Rng::new(0xE3);
    let pairs: Vec<(Fp64, Fp64)> = (0..1024)
        .map(|_| (Fp64(rng.nasty_bits64()), Fp64(rng.nasty_bits64())))
        .collect();
    for kind in SchemeKind::ALL {
        let mut m = DecompMul::new(kind);
        let mut i = 0;
        bench(&format!("fp64 mul via {}", kind.name()), 2_000, 30, 20_000, || {
            let (a, b) = pairs[i & 1023];
            i += 1;
            bb(a.mul_with(b, RoundMode::NearestEven, &mut m));
        });
    }
    let mut direct = civp::fpu::DirectMul;
    let mut i = 0;
    bench("fp64 mul via direct (no decomposition)", 2_000, 30, 20_000, || {
        let (a, b) = pairs[i & 1023];
        i += 1;
        bb(a.mul_with(b, RoundMode::NearestEven, &mut direct));
    });
}
