//! §Perf — lane-fused batch execution vs the per-op path, and the lane
//! width × vector-ISA ablation matrix.
//!
//! The tentpole claim of the lane engine: for a fixed scheme, walking the
//! compiled step table **once per block of operands** (tiles outer, lanes
//! inner, SoA buffers — `Plan::execute_lanes`) beats walking it once per
//! operand pair (`Plan::execute` in a loop, with per-element stats
//! merges — the pre-lane `execute_batch` shape). Measured at the two
//! levels the serving stack uses:
//!
//! * **raw significand products** — `lanes/civp-*/lane-path` vs
//!   `lanes/civp-*/per-op-path` for single/double/quad and a 48-bit
//!   "combined integer" width;
//! * **full IEEE pipeline** — `lanes/fpu-*/fused-x256` (`FpuBatch`:
//!   specials sidecar + one lane multiply + batched finish) vs
//!   `lanes/fpu-*/per-op-x256` (`mul_bits_batch`, the scalar pipeline per
//!   element — the pre-lane `NativeBackend` shape).
//!
//! The **ablation matrix** then sweeps the width-parameterized engine:
//! `lanes/simd-<class>/w{8,16,32}-{scalar,avx2,avx512,neon}` measures
//! `Plan::execute_lanes_cfg` for every block width × every vector ISA the
//! host offers (scalar rows always exist; SIMD rows only under
//! `--features simd` on a capable host). Every configuration is
//! cross-checked bit-identical to the per-op oracle before timing.
//!
//! Every measurement lands in `BENCH_lanes.json`; CI smoke-runs this
//! target (`CIVP_BENCH_QUICK=1`) and `python/tools/check_bench.py`
//! enforces `lane p50 ≤ per-op p50` for every pair and `simd p50 ≤
//! scalar p50` for every matrix row with a same-width scalar sibling, so
//! both the lane path and the SIMD sweeps gate every PR.

use civp::benchx::{bb, bench, scaled, section, verdict_table, JsonReport};
use civp::decomp::{
    DecompMul, ExecStats, LaneConfig, LaneWidth, OpClass, PlanCache, SchemeKind, SimdIsa,
};
use civp::fpu::{mul_bits_batch, FpuBatch, RoundMode};
use civp::proput::Rng;
use civp::wideint::{mul_u128, U128, U256};

const BATCH: usize = 256;

fn main() {
    let mut json = JsonReport::new();

    section("raw significand products x256: lane path vs per-op path");
    let mut verdicts: Vec<(String, f64)> = Vec::new();
    // Lane fusion is a U128-path engine; the wide classes run the tile
    // tree and are benched in `bench_formats` (Karatsuba ablation).
    let widths: Vec<(String, u32)> = OpClass::ALL
        .iter()
        .filter(|p| !p.is_wide())
        .map(|p| (format!("civp-{}", p.name()), p.sig_bits()))
        .chain(std::iter::once(("civp-int48".to_string(), 48)))
        .collect();
    for (label, bits) in &widths {
        let plan = PlanCache::get_width(SchemeKind::Civp, *bits);
        let mut rng = Rng::new(0x1A5E ^ *bits as u64);
        let a: Vec<U128> = (0..BATCH).map(|_| rng.sig(*bits)).collect();
        let b: Vec<U128> = (0..BATCH).map(|_| rng.sig(*bits)).collect();

        // Correctness cross-check before timing: lane ≡ per-op ≡ oracle.
        let mut st = ExecStats::default();
        let mut products: Vec<U256> = Vec::with_capacity(BATCH);
        plan.execute_lanes(&a, &b, &mut st, &mut products);
        assert_eq!(st.muls, BATCH as u64);
        for i in 0..BATCH {
            assert_eq!(products[i], mul_u128(a[i], b[i]), "lane path wrong at {i}");
        }

        let iters = scaled(2_000).max(4);
        let mut stats = ExecStats::default();
        let mut out: Vec<U256> = Vec::with_capacity(BATCH);
        let lane = bench(&format!("{label:<12} lane-path x256"), 20, 30, iters, || {
            plan.execute_lanes(&a, &b, &mut stats, &mut out);
            bb(out.len());
        });
        let mut stats = ExecStats::default();
        let mut out: Vec<U256> = Vec::with_capacity(BATCH);
        let perop = bench(&format!("{label:<12} per-op-path x256"), 20, 30, iters, || {
            // The pre-lane `execute_batch` shape: scalar kernel + one
            // stats merge per element.
            out.clear();
            for (&x, &y) in a.iter().zip(&b) {
                out.push(plan.execute(x, y, &mut stats));
            }
            bb(out.len());
        });
        json.push(&format!("lanes/{label}/lane-path"), lane);
        json.push(&format!("lanes/{label}/per-op-path"), perop);
        verdicts.push((label.clone(), lane.p50_speedup_over(&perop)));
    }

    section("full IEEE pipeline x256: FpuBatch fused vs per-op mul_bits_batch");
    for prec in OpClass::ALL.into_iter().filter(|c| !c.is_wide()) {
        let fmt = prec.format();
        let bits = fmt.total_bits();
        let mask = if bits == 128 { u128::MAX } else { (1u128 << bits) - 1 };
        let mut rng = Rng::new(0xF5E0 ^ bits as u64);
        let a: Vec<u128> = (0..BATCH)
            .map(|_| (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask)
            .collect();
        let b: Vec<u128> = (0..BATCH)
            .map(|_| (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask)
            .collect();

        let mut fused = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
        let mut out: Vec<u128> = Vec::with_capacity(BATCH);
        // Cross-check fused vs per-op before timing.
        let mut dm = DecompMul::new(SchemeKind::Civp);
        let mut want: Vec<u128> = Vec::new();
        let wf = mul_bits_batch(fmt, &a, &b, RoundMode::NearestEven, &mut dm, &mut want);
        let gf = fused.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out);
        assert_eq!(out, want, "fused pipeline diverged ({})", prec.name());
        assert_eq!(gf, wf, "fused flags diverged ({})", prec.name());

        let iters = scaled(500).max(2);
        let fused_m = bench(&format!("fpu-{:<8} fused x256", prec.name()), 10, 30, iters, || {
            fused.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out);
            bb(out.len());
        });
        let mut out2: Vec<u128> = Vec::with_capacity(BATCH);
        let perop_m = bench(&format!("fpu-{:<8} per-op x256", prec.name()), 10, 30, iters, || {
            mul_bits_batch(fmt, &a, &b, RoundMode::NearestEven, &mut dm, &mut out2);
            bb(out2.len());
        });
        json.push(&format!("lanes/fpu-{}/fused-x256", prec.name()), fused_m);
        json.push(&format!("lanes/fpu-{}/per-op-x256", prec.name()), perop_m);
        verdicts.push((format!("fpu-{}", prec.name()), fused_m.p50_speedup_over(&perop_m)));
    }

    section("ablation matrix: block width x vector ISA (execute_lanes_cfg)");
    println!(
        "host ISA: best available = {} (simd feature {})",
        SimdIsa::detect().name(),
        if cfg!(feature = "simd") { "on" } else { "off" }
    );
    let mut simd_verdicts: Vec<(String, f64)> = Vec::new();
    for class in OpClass::ALL.into_iter().filter(|c| !c.is_wide()) {
        let bits = class.sig_bits();
        let plan = PlanCache::get(SchemeKind::Civp, class);
        let mut rng = Rng::new(0x51D0 ^ bits as u64);
        let a: Vec<U128> = (0..BATCH).map(|_| rng.sig(bits)).collect();
        let b: Vec<U128> = (0..BATCH).map(|_| rng.sig(bits)).collect();
        let iters = scaled(1_000).max(4);
        for width in LaneWidth::ALL {
            let mut scalar_p50 = None;
            for isa in SimdIsa::ALL {
                if !isa.available() {
                    continue;
                }
                let cfg = LaneConfig { width, isa };
                // Cross-check before timing: every width × ISA is
                // bit-identical to the per-op oracle.
                let mut st = ExecStats::default();
                let mut products: Vec<U256> = Vec::with_capacity(BATCH);
                plan.execute_lanes_cfg(cfg, &a, &b, &mut st, &mut products);
                for i in 0..BATCH {
                    assert_eq!(
                        products[i],
                        mul_u128(a[i], b[i]),
                        "{} {} diverged at {i}",
                        class.name(),
                        cfg.kernel_name()
                    );
                }
                let mut stats = ExecStats::default();
                let mut out: Vec<U256> = Vec::with_capacity(BATCH);
                let label = format!("{:<8} {}", class.name(), cfg.kernel_name());
                let m = bench(&label, 20, 30, iters, || {
                    plan.execute_lanes_cfg(cfg, &a, &b, &mut stats, &mut out);
                    bb(out.len());
                });
                json.push(
                    &format!("lanes/simd-{}/{}-{}", class.name(), width.name(), isa.name()),
                    m,
                );
                match isa {
                    SimdIsa::Scalar => scalar_p50 = Some(m),
                    _ => {
                        let scalar = scalar_p50.expect("scalar ISA measured first");
                        simd_verdicts.push((
                            format!("{}/{}", class.name(), cfg.kernel_name()),
                            m.p50_speedup_over(&scalar),
                        ));
                    }
                }
            }
        }
    }

    verdict_table(
        "verdict: lane/fused speedup over the per-op path (p50)",
        &verdicts,
        "the lane path beats the per-op path on every measured configuration",
        "at least one configuration did not benefit from lane fusion",
    );
    if !simd_verdicts.is_empty() {
        verdict_table(
            "verdict: SIMD sweep speedup over same-width scalar (p50)",
            &simd_verdicts,
            "every dispatched SIMD kernel beats its same-width scalar sweep",
            "at least one SIMD kernel ran slower than its scalar sibling",
        );
    }

    json.write("BENCH_lanes.json").expect("write BENCH_lanes.json");
}
