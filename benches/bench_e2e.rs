//! E7 — end-to-end serving benchmark: the three-layer system on mixed
//! precision multimedia traffic.
//!
//! For each workload mix: drive the coordinator (native backend) and
//! report throughput + latency; run the same op mix through the fabric
//! cycle/energy model under the CIVP fabric and the iso-area legacy fabric
//! to get the paper's hardware-level comparison. Also times the PJRT
//! backend (batched artifact dispatch) when artifacts are present.
//!
//! §Perf paths covered explicitly:
//!
//! * steady-state submit→response throughput through the pooled oneshot
//!   reply slots (vs an `mpsc::channel`-per-request baseline, the pre-PR
//!   reply path, timed side by side);
//! * count-based `simulate_counts` fabric reporting vs materializing the
//!   op stream and replaying it through `simulate_stream` (the pre-PR
//!   `fabric_report` shape), at 1M ops;
//! * results land in `BENCH_e2e.json` at the repo root (see README
//!   "Benchmarks") so the perf trajectory is tracked run over run.
//!
//! `CIVP_BENCH_QUICK=1` shrinks every workload for CI smoke runs.

use civp::benchx::{bb, bench, scaled, section, wall_measurement, JsonReport};
use civp::config::ServiceConfig;
use civp::coordinator::{BackendChoice, ReplyPool, Response, Service};
use civp::decomp::{OpClass, SchemeKind};
use civp::fabric::{simulate_counts, simulate_stream, CostModel, FabricConfig, FabricOp};
use civp::runtime::EngineHandle;
use civp::trace::{TraceGen, WorkloadSpec};
use civp::wideint::PackedBits;
use std::collections::BTreeMap;
use std::time::Instant;

fn requests() -> usize {
    scaled(20_000) as usize
}

fn drive(svc: &Service, trace: &[civp::trace::TraceRequest]) -> f64 {
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(4096);
    for req in trace {
        pending.push(svc.submit(req.id, req.class, req.a, req.b).unwrap());
        if pending.len() >= 4096 {
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let cost = CostModel::default();
    let mut json = JsonReport::new();
    let n_requests = requests();

    for workload in WorkloadSpec::ALL {
        section(&format!("E7 workload `{}`", workload.name()));
        let trace = TraceGen::new(0xE7, workload.mix(), 0).take(n_requests);

        // --- serving layer (native backend) ---------------------------
        let cfg = ServiceConfig::default();
        let svc = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));
        let wall = drive(&svc, &trace);
        let rep = svc.shutdown();
        println!(
            "coordinator (native): {:>8.0} mult/s  ({n_requests} reqs in {wall:.3}s)",
            n_requests as f64 / wall,
        );
        json.push(
            &format!("e2e/{}/native-submit-response", workload.name()),
            wall_measurement(n_requests as u64, wall),
        );
        for p in OpClass::ALL.map(|c| c.name()) {
            if let Some(h) = rep.snapshot.hists.get(&format!("latency_ns_{p}")) {
                if h.count > 0 {
                    println!(
                        "  latency {p:<7} p50={:>9}ns p99={:>9}ns n={}",
                        h.p50, h.p99, h.count
                    );
                }
            }
        }

        // --- fabric layer: civp vs iso-area legacy ---------------------
        // Per-class counts are all the cycle/energy model needs; no
        // materialized op stream (§Perf).
        let mut civp_counts: BTreeMap<FabricOp, u64> = BTreeMap::new();
        let mut b18_counts: BTreeMap<FabricOp, u64> = BTreeMap::new();
        for r in &trace {
            *civp_counts
                .entry(FabricOp { class: r.class, organization: SchemeKind::Civp })
                .or_insert(0) += 1;
            *b18_counts
                .entry(FabricOp { class: r.class, organization: SchemeKind::Baseline18 })
                .or_insert(0) += 1;
        }
        let rc = simulate_counts(&civp_counts, &FabricConfig::civp_scaled(1), &cost);
        let rb = simulate_counts(&b18_counts, &FabricConfig::legacy_iso_area(1), &cost);
        println!(
            "fabric civp      : {:>8} cycles  {:>7.3} E/op  {:>5.1}% wasted",
            rc.cycles,
            rc.energy_per_op(),
            rc.wasted_fraction() * 100.0
        );
        println!(
            "fabric iso-18x18 : {:>8} cycles  {:>7.3} E/op  {:>5.1}% wasted",
            rb.cycles,
            rb.energy_per_op(),
            rb.wasted_fraction() * 100.0
        );
        println!(
            "civp advantage   : {:.2}x cycles, {:.2}x energy/op, {:.1}x waste",
            rb.cycles as f64 / rc.cycles as f64,
            rb.energy_per_op() / rc.energy_per_op(),
            rb.wasted_fraction() / rc.wasted_fraction().max(1e-9)
        );
    }

    // --- reply path: pooled oneshot vs per-request mpsc channel --------
    section("reply path: pooled oneshot slot vs mpsc channel per request (pre-PR)");
    let resp = Response { id: 1, bits: PackedBits::from_u64(42), latency_ns: 100, batch_size: 8 };
    let pool = ReplyPool::new();
    let iters = scaled(20_000);
    let oneshot = bench("reply roundtrip: pooled oneshot", 1_000, 30, iters, || {
        let (tx, rx) = pool.acquire();
        tx.send(resp);
        bb(rx.recv().unwrap().bits);
    });
    let mpsc = bench("reply roundtrip: mpsc channel (pre-PR)", 1_000, 30, iters, || {
        let (tx, rx) = std::sync::mpsc::channel();
        tx.send(resp).unwrap();
        bb(rx.recv().unwrap().bits);
    });
    println!(
        "pooled oneshot is {:.2}x the mpsc reply path (p50)",
        mpsc.ns_per_op_p50 / oneshot.ns_per_op_p50
    );
    json.push("reply/pooled-oneshot", oneshot);
    json.push("reply/mpsc-channel-pre-pr", mpsc);

    // --- fabric report: O(#classes) counts vs O(#ops) replay -----------
    section("fabric report at 1M ops: simulate_counts vs materialized simulate_stream");
    let total: u64 = scaled(1_000_000);
    let mut counts: BTreeMap<FabricOp, u64> = BTreeMap::new();
    counts.insert(
        FabricOp { class: OpClass::Single, organization: SchemeKind::Civp },
        total / 2,
    );
    counts.insert(
        FabricOp { class: OpClass::Double, organization: SchemeKind::Civp },
        total / 3,
    );
    counts.insert(
        FabricOp { class: OpClass::Quad, organization: SchemeKind::Civp },
        total - total / 2 - total / 3,
    );
    let fabric = FabricConfig::civp_scaled(1);
    let from_counts = bench("fabric_report: simulate_counts", 10, 20, 50, || {
        bb(simulate_counts(&counts, &fabric, &cost));
    });
    let from_stream = bench("fabric_report: replay simulate_stream (pre-PR)", 2, 10, 1, || {
        // The pre-PR shape: materialize one FabricOp per executed multiply,
        // then aggregate it all over again.
        let mut ops: Vec<FabricOp> = Vec::with_capacity(total as usize);
        for (class, n) in &counts {
            for _ in 0..*n {
                ops.push(*class);
            }
        }
        bb(simulate_stream(&ops, &fabric, &cost));
    });
    println!(
        "count-based report is {:.0}x faster than per-op replay at {total} ops",
        from_stream.ns_per_op_p50 / from_counts.ns_per_op_p50,
    );
    json.push("fabric-report/simulate-counts", from_counts);
    json.push("fabric-report/replay-stream-pre-pr", from_stream);

    // --- PJRT backend timing (graphics mix) ----------------------------
    section("E7 PJRT backend (AOT JAX/Pallas artifacts)");
    match EngineHandle::load("artifacts") {
        Ok(handle) => {
            let info = handle.info().unwrap();
            let trace =
                TraceGen::new(0xE7, WorkloadSpec::Graphics.mix(), 0).take(n_requests / 4);
            let cfg = ServiceConfig { max_batch: info.batch, linger_us: 500, ..Default::default() };
            let svc = Service::start(&cfg, BackendChoice::Pjrt(handle.clone()));
            let wall = drive(&svc, &trace);
            let rep = svc.shutdown();
            println!(
                "coordinator (pjrt): {:>8.0} mult/s  ({} reqs in {wall:.3}s, batch={})",
                trace.len() as f64 / wall,
                trace.len(),
                info.batch
            );
            json.push(
                "e2e/graphics/pjrt-submit-response",
                wall_measurement(trace.len() as u64, wall),
            );
            let _ = rep;
            handle.stop();
        }
        Err(e) => println!("skipped (artifacts not built): {e:#}"),
    }

    json.write("BENCH_e2e.json").expect("write BENCH_e2e.json");
}
