//! E7 — end-to-end serving benchmark: the three-layer system on mixed
//! precision multimedia traffic.
//!
//! For each workload mix: drive the coordinator (native backend) and
//! report throughput + latency; replay the same op mix through the fabric
//! simulator under the CIVP fabric and the iso-area legacy fabric to get
//! the paper's hardware-level comparison. Also times the PJRT backend
//! (batched artifact dispatch) when artifacts are present.

use civp::benchx::section;
use civp::config::ServiceConfig;
use civp::coordinator::{BackendChoice, Service};
use civp::decomp::SchemeKind;
use civp::fabric::{simulate_stream, CostModel, FabricConfig, OpClass};
use civp::runtime::EngineHandle;
use civp::trace::{TraceGen, WorkloadSpec};
use std::time::Instant;

const REQUESTS: usize = 20_000;

fn drive(svc: &Service, trace: &[civp::trace::TraceRequest]) -> f64 {
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(4096);
    for req in trace {
        pending.push(svc.submit(req.id, req.precision, req.a, req.b).unwrap());
        if pending.len() >= 4096 {
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let cost = CostModel::default();

    for workload in WorkloadSpec::ALL {
        section(&format!("E7 workload `{}`", workload.name()));
        let trace = TraceGen::new(0xE7, workload.mix(), 0).take(REQUESTS);

        // --- serving layer (native backend) ---------------------------
        let cfg = ServiceConfig::default();
        let svc = Service::start(&cfg, BackendChoice::Native(SchemeKind::Civp));
        let wall = drive(&svc, &trace);
        let rep = svc.shutdown();
        println!(
            "coordinator (native): {:>8.0} mult/s  ({} reqs in {:.3}s)",
            REQUESTS as f64 / wall,
            REQUESTS,
            wall
        );
        for p in ["single", "double", "quad"] {
            if let Some(h) = rep.snapshot.hists.get(&format!("latency_ns_{p}")) {
                if h.count > 0 {
                    println!("  latency {p:<7} p50={:>9}ns p99={:>9}ns n={}", h.p50, h.p99, h.count);
                }
            }
        }

        // --- fabric layer: civp vs iso-area legacy ---------------------
        let civp_ops: Vec<OpClass> = trace
            .iter()
            .map(|r| OpClass { precision: r.precision, organization: SchemeKind::Civp })
            .collect();
        let b18_ops: Vec<OpClass> = trace
            .iter()
            .map(|r| OpClass { precision: r.precision, organization: SchemeKind::Baseline18 })
            .collect();
        let rc = simulate_stream(&civp_ops, &FabricConfig::civp_scaled(1), &cost);
        let rb = simulate_stream(&b18_ops, &FabricConfig::legacy_iso_area(1), &cost);
        println!(
            "fabric civp      : {:>8} cycles  {:>7.3} E/op  {:>5.1}% wasted",
            rc.cycles,
            rc.energy_per_op(),
            rc.wasted_fraction() * 100.0
        );
        println!(
            "fabric iso-18x18 : {:>8} cycles  {:>7.3} E/op  {:>5.1}% wasted",
            rb.cycles,
            rb.energy_per_op(),
            rb.wasted_fraction() * 100.0
        );
        println!(
            "civp advantage   : {:.2}x cycles, {:.2}x energy/op, {:.1}x waste",
            rb.cycles as f64 / rc.cycles as f64,
            rb.energy_per_op() / rc.energy_per_op(),
            rb.wasted_fraction() / rc.wasted_fraction().max(1e-9)
        );
    }

    // --- PJRT backend timing (graphics mix) ----------------------------
    section("E7 PJRT backend (AOT JAX/Pallas artifacts)");
    match EngineHandle::load("artifacts") {
        Ok(handle) => {
            let info = handle.info().unwrap();
            let trace = TraceGen::new(0xE7, WorkloadSpec::Graphics.mix(), 0).take(REQUESTS / 4);
            let cfg = ServiceConfig { max_batch: info.batch, linger_us: 500, ..Default::default() };
            let svc = Service::start(&cfg, BackendChoice::Pjrt(handle.clone()));
            let wall = drive(&svc, &trace);
            let rep = svc.shutdown();
            println!(
                "coordinator (pjrt): {:>8.0} mult/s  ({} reqs in {:.3}s, batch={})",
                trace.len() as f64 / wall,
                trace.len(),
                wall,
                info.batch
            );
            let _ = rep;
            handle.stop();
        }
        Err(e) => println!("skipped (artifacts not built): {e:#}"),
    }
}
