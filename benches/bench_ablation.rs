//! E8 — ablations over the design choices DESIGN.md calls out:
//!
//! * padding placement for DP (pad-high [24,24,9] per Fig. 2 vs
//!   alternative chunk orders) — does the chunk order matter for cost?
//! * batcher policy (linger / max-batch) — latency/throughput trade.
//! * fabric provisioning scale — where does the coordinator stop being
//!   fabric-bound?

use civp::benchx::section;
use civp::config::ServiceConfig;
use civp::coordinator::{BackendChoice, Service};
use civp::decomp::{scheme_census, OpClass, Scheme, SchemeKind};
use civp::fabric::{simulate_stream, CostModel, FabricConfig, FabricOp};
use civp::trace::{TraceGen, WorkloadSpec};
use civp::wideint::{mul_u128, U128};
use std::time::Instant;

fn main() {
    // ------------------------------------------------------------------
    section("E8a: DP chunk-order ablation (all orders of [24,24,9])");
    // The tile *multiset* is order-invariant; what changes is where the
    // padding lands (which chunk is partially filled). Fig. 2 puts the
    // 9-bit chunk at the top (pad-high).
    let orders: [(&str, Vec<u32>); 3] = [
        ("fig2 [24,24,9] (pad in 9-chunk)", vec![24, 24, 9]),
        ("alt  [9,24,24] (pad in top 24)", vec![9, 24, 24]),
        ("alt  [24,9,24] (pad in top 24)", vec![24, 9, 24]),
    ];
    println!("{:<36} {:>8} {:>8} {:>8}", "order", "padded", "util%", "exact?");
    for (label, chunks) in orders {
        let mut scheme = Scheme::new(SchemeKind::Civp, civp::decomp::OpClass::Double);
        scheme.a_chunks = chunks.clone();
        scheme.b_chunks = chunks;
        let census = scheme_census(&scheme);
        // exactness: decomposition must stay exact regardless of order
        let a = U128::from_u128((1u128 << 53) - 1);
        let b = U128::from_u128(0x1A2B3C4D5E6F7 | (1u128 << 52));
        let mut stats = civp::decomp::ExecStats::default();
        let exact = civp::decomp::execute(&scheme, a, b, &mut stats) == mul_u128(a, b);
        println!(
            "{label:<36} {:>8} {:>8.1} {exact:>8}",
            census.padded_blocks,
            census.utilization * 100.0,
        );
    }
    println!("(tile multiset is identical; Fig. 2's order confines padding to the 9x9/24x9 tiles)");

    // ------------------------------------------------------------------
    section("E8b: batcher policy (graphics mix, native backend, 10k reqs)");
    println!(
        "{:<28} {:>12} {:>12} {:>12}",
        "policy", "mult/s", "p50 batch", "p99 lat(ns)"
    );
    for (max_batch, linger_us) in
        [(1usize, 0u64), (32, 50), (64, 100), (256, 200), (256, 1000), (1024, 2000)]
    {
        let cfg = ServiceConfig {
            max_batch,
            linger_us,
            queue_depth: 8192.max(max_batch),
            ..Default::default()
        };
        let svc = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));
        let trace = TraceGen::new(0xE8, WorkloadSpec::Graphics.mix(), 0).take(10_000);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        for req in &trace {
            pending.push(svc.submit(req.id, req.class, req.a, req.b).unwrap());
            if pending.len() >= 2048 {
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        let wall = t0.elapsed().as_secs_f64();
        let rep = svc.shutdown();
        let batch_p50 = rep
            .snapshot
            .hists
            .get("batch_size_single")
            .map(|h| h.p50)
            .unwrap_or(0);
        let lat_p99 = rep
            .snapshot
            .hists
            .get("latency_ns_single")
            .map(|h| h.p99)
            .unwrap_or(0);
        println!(
            "{:<28} {:>12.0} {batch_p50:>12} {lat_p99:>12}",
            format!("max={max_batch} linger={linger_us}us"),
            10_000.0 / wall,
        );
    }

    // ------------------------------------------------------------------
    section("E8c: fabric provisioning scale (uniform mix, 30k ops)");
    let cost = CostModel::default();
    let ops: Vec<FabricOp> = TraceGen::new(0xE8C, WorkloadSpec::Uniform.mix(), 0)
        .take(30_000)
        .into_iter()
        .map(|r| FabricOp { class: r.class, organization: SchemeKind::Civp })
        .collect();
    println!("{:<10} {:>10} {:>12} {:>12}", "scale", "cycles", "ops/cycle", "E/op");
    for scale in [1u32, 2, 4, 8] {
        let r = simulate_stream(&ops, &FabricConfig::civp_scaled(scale), &cost);
        println!(
            "{:<10} {:>10} {:>12.3} {:>12.3}",
            format!("civp-x{scale}"),
            r.cycles,
            r.throughput(),
            r.energy_per_op()
        );
    }
    println!("(throughput scales ~linearly with provisioned columns; energy/op is flat\n because static leakage amortizes over proportionally fewer cycles)");

    // ------------------------------------------------------------------
    section("E8d: paper §III future work — self-repair + power gating");
    // Self-repair: inject sub-unit faults into the 24x24 bank and watch the
    // quad schedule degrade gracefully (spares absorb early faults).
    use civp::fabric::{gating_report, schedule_op, FaultOutcome, RepairableFabric};
    println!(
        "{:<10} {:>9} {:>10} {:>8} {:>10}",
        "faults", "repaired", "lost-blk", "health%", "QP waves"
    );
    for spares in [2u32] {
        let mut fab = RepairableFabric::new(FabricConfig::civp_scaled(1), spares);
        let mut rng = civp::proput::Rng::new(0xE8D);
        let scheme = Scheme::new(SchemeKind::Civp, civp::decomp::OpClass::Quad);
        let mut repaired = 0u64;
        let mut lost = 0u32;
        for injected in [0u32, 8, 16, 32, 48] {
            while (repaired + lost as u64) < injected as u64 {
                match fab.inject_fault(civp::decomp::BlockKind::M24x24, &mut rng) {
                    FaultOutcome::Repaired => repaired += 1,
                    FaultOutcome::BlockLost => lost += 1,
                    FaultOutcome::NoTarget => break,
                }
            }
            let cfg = fab.effective_config();
            let waves = if cfg.count(civp::decomp::BlockKind::M24x24) == 0 {
                "dead".to_string()
            } else {
                schedule_op(&scheme, &cfg, &cost).initiation_interval.to_string()
            };
            println!(
                "{injected:<10} {repaired:>9} {lost:>10} {:>8.1} {waves:>10}",
                fab.health() * 100.0,
            );
        }
    }
    // Power gating: dynamic energy with unused 12x12 sub-units gated off,
    // per precision and organization (the "considerable dynamic power
    // saving" the paper promises from the reconfigurable 24x24).
    println!(
        "\n{:<10} {:<8} {:>10} {:>10} {:>9}",
        "precision", "scheme", "fixed-E", "gated-E", "saving%"
    );
    for prec in civp::decomp::OpClass::ALL {
        for kind in [SchemeKind::Civp, SchemeKind::Baseline18] {
            let tiles = Scheme::new(kind, prec).tiles();
            let (gated, fixed) = gating_report(&cost, &tiles);
            println!(
                "{:<10} {:<8} {fixed:>10.3} {gated:>10.3} {:>9.1}",
                prec.name(),
                kind.name(),
                (1.0 - gated / fixed) * 100.0
            );
        }
    }
}
