//! Per-class format benchmarks over the open op-class registry: the lane
//! path vs the per-op path for *every* served class — the paper's three
//! precisions plus the sub-single formats (binary16, bfloat16) landed by
//! the registry refactor.
//!
//! Two levels per class, mirroring `bench_lanes`:
//!
//! * **raw significand products** — `formats/civp-<class>/lane-path` vs
//!   `formats/civp-<class>/per-op-path` (`Plan::execute_lanes` vs
//!   `Plan::execute` in a loop);
//! * **full IEEE pipeline** — `formats/fpu-<class>/fused-x256`
//!   (`FpuBatch`) vs `formats/fpu-<class>/per-op-x256` (`mul_bits_batch`).
//!
//! Every measurement lands in `BENCH_formats.json`; CI smoke-runs this
//! target and `python/tools/check_bench.py` enforces `lane p50 ≤ per-op
//! p50` per pair, so the sub-single classes gate regressions exactly like
//! the original three.

use civp::benchx::{bb, bench, scaled, section, verdict_table, JsonReport};
use civp::decomp::{DecompMul, ExecStats, OpClass, PlanCache, SchemeKind};
use civp::fpu::{mul_bits_batch, FpuBatch, RoundMode};
use civp::proput::Rng;
use civp::wideint::{mul_u128, U128, U256};

const BATCH: usize = 256;

fn main() {
    let mut json = JsonReport::new();

    section("raw significand products x256 per registry class");
    let mut verdicts: Vec<(String, f64)> = Vec::new();
    for class in OpClass::ALL {
        let label = format!("civp-{}", class.name());
        let bits = class.sig_bits();
        let plan = PlanCache::get(SchemeKind::Civp, class);
        let mut rng = Rng::new(0xF0A7 ^ bits as u64);
        let a: Vec<U128> = (0..BATCH).map(|_| rng.sig(bits)).collect();
        let b: Vec<U128> = (0..BATCH).map(|_| rng.sig(bits)).collect();

        // Correctness cross-check before timing: lane ≡ oracle.
        let mut st = ExecStats::default();
        let mut products: Vec<U256> = Vec::with_capacity(BATCH);
        plan.execute_lanes(&a, &b, &mut st, &mut products);
        for i in 0..BATCH {
            assert_eq!(products[i], mul_u128(a[i], b[i]), "{label} lane path wrong at {i}");
        }

        let iters = scaled(2_000).max(4);
        let mut stats = ExecStats::default();
        let mut out: Vec<U256> = Vec::with_capacity(BATCH);
        let lane = bench(&format!("{label:<12} lane-path x256"), 20, 30, iters, || {
            plan.execute_lanes(&a, &b, &mut stats, &mut out);
            bb(out.len());
        });
        let mut stats = ExecStats::default();
        let mut out: Vec<U256> = Vec::with_capacity(BATCH);
        let perop = bench(&format!("{label:<12} per-op-path x256"), 20, 30, iters, || {
            out.clear();
            for (&x, &y) in a.iter().zip(&b) {
                out.push(plan.execute(x, y, &mut stats));
            }
            bb(out.len());
        });
        json.push(&format!("formats/{label}/lane-path"), lane);
        json.push(&format!("formats/{label}/per-op-path"), perop);
        verdicts.push((label, lane.p50_speedup_over(&perop)));
    }

    section("full IEEE pipeline x256 per registry class: fused vs per-op");
    for class in OpClass::ALL {
        let fmt = class.format();
        let bits = fmt.total_bits();
        let mask = if bits == 128 { u128::MAX } else { (1u128 << bits) - 1 };
        let mut rng = Rng::new(0xF0E0 ^ bits as u64);
        let a: Vec<u128> = (0..BATCH)
            .map(|_| (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask)
            .collect();
        let b: Vec<u128> = (0..BATCH)
            .map(|_| (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask)
            .collect();

        let mut fused = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
        let mut out: Vec<u128> = Vec::with_capacity(BATCH);
        // Cross-check fused vs per-op before timing.
        let mut dm = DecompMul::new(SchemeKind::Civp);
        let mut want: Vec<u128> = Vec::new();
        let wf = mul_bits_batch(fmt, &a, &b, RoundMode::NearestEven, &mut dm, &mut want);
        let gf = fused.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out);
        assert_eq!(out, want, "fused pipeline diverged ({})", class.name());
        assert_eq!(gf, wf, "fused flags diverged ({})", class.name());

        let iters = scaled(500).max(2);
        let fused_m = bench(&format!("fpu-{:<8} fused x256", class.name()), 10, 30, iters, || {
            fused.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out);
            bb(out.len());
        });
        let mut out2: Vec<u128> = Vec::with_capacity(BATCH);
        let perop_m = bench(&format!("fpu-{:<8} per-op x256", class.name()), 10, 30, iters, || {
            mul_bits_batch(fmt, &a, &b, RoundMode::NearestEven, &mut dm, &mut out2);
            bb(out2.len());
        });
        json.push(&format!("formats/fpu-{}/fused-x256", class.name()), fused_m);
        json.push(&format!("formats/fpu-{}/per-op-x256", class.name()), perop_m);
        verdicts.push((format!("fpu-{}", class.name()), fused_m.p50_speedup_over(&perop_m)));
    }

    verdict_table(
        "verdict: lane/fused speedup per class (p50)",
        &verdicts,
        "the lane path beats the per-op path on every registry class",
        "at least one class did not benefit from lane fusion",
    );

    json.write("BENCH_formats.json").expect("write BENCH_formats.json");
}
