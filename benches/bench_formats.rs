//! Per-class format benchmarks over the open op-class registry: the lane
//! path vs the per-op path for *every* served class — the paper's three
//! precisions plus the sub-single formats (binary16, bfloat16) landed by
//! the registry refactor.
//!
//! Two levels per class, mirroring `bench_lanes`:
//!
//! * **raw significand products** — `formats/civp-<class>/lane-path` vs
//!   `formats/civp-<class>/per-op-path` (`Plan::execute_lanes` vs
//!   `Plan::execute` in a loop);
//! * **full IEEE pipeline** — `formats/fpu-<class>/fused-x256`
//!   (`FpuBatch`) vs `formats/fpu-<class>/per-op-x256` (`mul_bits_batch`).
//!
//! The **wide ablation** then takes the tree-path classes (Fp256/Fp512,
//! which bypass the U128 lane engine entirely): for each one it times
//! `Plan::execute_batch_wide` under the naive all-pairs organization
//! (`civp`) against the sub-quadratic `karatsuba24` planner, and records
//! the static per-multiply tile counts of both plans —
//! `formats/wide-<class>/{naive,karatsuba}-x64` and
//! `formats/wide-<class>/tile-count-{naive,karatsuba}`.
//!
//! Every measurement lands in `BENCH_formats.json`; CI smoke-runs this
//! target and `python/tools/check_bench.py` enforces `lane p50 ≤ per-op
//! p50` per pair plus the Karatsuba ablation gate (`karatsuba p50 ≤
//! naive p50` and sub-quadratic tile growth at every wide class), so the
//! sub-single and wide classes gate regressions exactly like the
//! original three.

use civp::benchx::{bb, bench, scaled, section, verdict_table, JsonReport, Measurement};
use civp::decomp::{DecompMul, ExecStats, OpClass, Plan, PlanCache, SchemeKind};
use civp::fpu::{mul_bits_batch, FpuBatch, RoundMode, WideProd};
use civp::proput::Rng;
use civp::wideint::{mul_u128, PackedBits, U128, U256};

const BATCH: usize = 256;

fn main() {
    let mut json = JsonReport::new();

    section("raw significand products x256 per registry class");
    let mut verdicts: Vec<(String, f64)> = Vec::new();
    // The lane/per-op pair covers the U128-path classes; the wide classes
    // (tree path) get their own naive-vs-karatsuba ablation below.
    for class in OpClass::ALL.into_iter().filter(|c| !c.is_wide()) {
        let label = format!("civp-{}", class.name());
        let bits = class.sig_bits();
        let plan = PlanCache::get(SchemeKind::Civp, class);
        let mut rng = Rng::new(0xF0A7 ^ bits as u64);
        let a: Vec<U128> = (0..BATCH).map(|_| rng.sig(bits)).collect();
        let b: Vec<U128> = (0..BATCH).map(|_| rng.sig(bits)).collect();

        // Correctness cross-check before timing: lane ≡ oracle.
        let mut st = ExecStats::default();
        let mut products: Vec<U256> = Vec::with_capacity(BATCH);
        plan.execute_lanes(&a, &b, &mut st, &mut products);
        for i in 0..BATCH {
            assert_eq!(products[i], mul_u128(a[i], b[i]), "{label} lane path wrong at {i}");
        }

        let iters = scaled(2_000).max(4);
        let mut stats = ExecStats::default();
        let mut out: Vec<U256> = Vec::with_capacity(BATCH);
        let lane = bench(&format!("{label:<12} lane-path x256"), 20, 30, iters, || {
            plan.execute_lanes(&a, &b, &mut stats, &mut out);
            bb(out.len());
        });
        let mut stats = ExecStats::default();
        let mut out: Vec<U256> = Vec::with_capacity(BATCH);
        let perop = bench(&format!("{label:<12} per-op-path x256"), 20, 30, iters, || {
            out.clear();
            for (&x, &y) in a.iter().zip(&b) {
                out.push(plan.execute(x, y, &mut stats));
            }
            bb(out.len());
        });
        json.push(&format!("formats/{label}/lane-path"), lane);
        json.push(&format!("formats/{label}/per-op-path"), perop);
        verdicts.push((label, lane.p50_speedup_over(&perop)));
    }

    section("full IEEE pipeline x256 per registry class: fused vs per-op");
    for class in OpClass::ALL.into_iter().filter(|c| !c.is_wide()) {
        let fmt = class.format();
        let bits = fmt.total_bits();
        let mask = if bits == 128 { u128::MAX } else { (1u128 << bits) - 1 };
        let mut rng = Rng::new(0xF0E0 ^ bits as u64);
        let a: Vec<u128> = (0..BATCH)
            .map(|_| (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask)
            .collect();
        let b: Vec<u128> = (0..BATCH)
            .map(|_| (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask)
            .collect();

        let mut fused = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
        let mut out: Vec<u128> = Vec::with_capacity(BATCH);
        // Cross-check fused vs per-op before timing.
        let mut dm = DecompMul::new(SchemeKind::Civp);
        let mut want: Vec<u128> = Vec::new();
        let wf = mul_bits_batch(fmt, &a, &b, RoundMode::NearestEven, &mut dm, &mut want);
        let gf = fused.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out);
        assert_eq!(out, want, "fused pipeline diverged ({})", class.name());
        assert_eq!(gf, wf, "fused flags diverged ({})", class.name());

        let iters = scaled(500).max(2);
        let fused_m = bench(&format!("fpu-{:<8} fused x256", class.name()), 10, 30, iters, || {
            fused.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out);
            bb(out.len());
        });
        let mut out2: Vec<u128> = Vec::with_capacity(BATCH);
        let perop_m = bench(&format!("fpu-{:<8} per-op x256", class.name()), 10, 30, iters, || {
            mul_bits_batch(fmt, &a, &b, RoundMode::NearestEven, &mut dm, &mut out2);
            bb(out2.len());
        });
        json.push(&format!("formats/fpu-{}/fused-x256", class.name()), fused_m);
        json.push(&format!("formats/fpu-{}/per-op-x256", class.name()), perop_m);
        verdicts.push((format!("fpu-{}", class.name()), fused_m.p50_speedup_over(&perop_m)));
    }

    verdict_table(
        "verdict: lane/fused speedup per class (p50)",
        &verdicts,
        "the lane path beats the per-op path on every registry class",
        "at least one class did not benefit from lane fusion",
    );

    section("wide ablation x64: karatsuba24 planner vs naive all-pairs tiling");
    const WIDE_BATCH: usize = 64;
    let mut wide_verdicts: Vec<(String, f64)> = Vec::new();
    for class in OpClass::ALL.into_iter().filter(|c| c.is_wide()) {
        let bits = class.sig_bits();
        let mut rng = Rng::new(0xF1DE ^ bits as u64);
        let mut draw = |rng: &mut Rng| {
            let mut v = PackedBits::ZERO;
            for l in v.limbs.iter_mut() {
                *l = rng.next_u64();
            }
            let mut v = v.mask_low(bits);
            v.set_bit(bits - 1); // normalized significand: top bit set
            v
        };
        let a: Vec<PackedBits> = (0..WIDE_BATCH).map(|_| draw(&mut rng)).collect();
        let b: Vec<PackedBits> = (0..WIDE_BATCH).map(|_| draw(&mut rng)).collect();

        let naive_plan = PlanCache::get(SchemeKind::Civp, class);
        let kara_plan = PlanCache::get(SchemeKind::Karatsuba24, class);

        // Correctness cross-check before timing: both organizations must
        // reproduce the exact double-width product.
        let mut st = ExecStats::default();
        for i in 0..WIDE_BATCH {
            let want: WideProd = a[i].mul_full(&b[i]);
            assert_eq!(naive_plan.execute_wide(a[i], b[i], &mut st), want, "naive {i}");
            assert_eq!(kara_plan.execute_wide(a[i], b[i], &mut st), want, "karatsuba {i}");
        }

        let iters = scaled(300).max(2);
        let mut run = |tag: &str, plan: &Plan| -> Measurement {
            let mut stats = ExecStats::default();
            let mut out: Vec<WideProd> = Vec::with_capacity(WIDE_BATCH);
            let label = format!("{:<8} {tag:<10} x{WIDE_BATCH}", class.name());
            let m = bench(&label, 10, 30, iters, || {
                plan.execute_batch_wide(&a, &b, &mut stats, &mut out);
                bb(out.len());
            });
            json.push(&format!("formats/wide-{}/{tag}-x{WIDE_BATCH}", class.name()), m);
            // Static tile census per multiply, stored as a pseudo-measurement
            // so check_bench.py can gate sub-quadratic growth from the JSON.
            let tiles = plan.per_mul_stats().tiles;
            json.push(
                &format!("formats/wide-{}/tile-count-{tag}", class.name()),
                Measurement::uniform(tiles as f64, tiles),
            );
            println!("  {:<8} {tag:<10} {tiles} tiles/mul", class.name());
            m
        };
        let naive_m = run("naive", &naive_plan);
        let kara_m = run("karatsuba", &kara_plan);
        wide_verdicts.push((format!("wide-{}", class.name()), kara_m.p50_speedup_over(&naive_m)));
    }
    if !wide_verdicts.is_empty() {
        verdict_table(
            "verdict: karatsuba24 speedup over naive all-pairs at wide widths (p50)",
            &wide_verdicts,
            "the sub-quadratic planner beats all-pairs tiling on every wide class",
            "at least one wide class did not benefit from the karatsuba planner",
        );
    }

    json.write("BENCH_formats.json").expect("write BENCH_formats.json");
}
