//! §Perf — multi-core batch execution through the work-stealing
//! [`Executor`] at 1/2/4/8 cores, batch sizes spanning the parallel
//! threshold (256): 128 stays sequential, 1024 and 8192 fan out.
//!
//! Two families of measurements land in `BENCH_parallel.json`:
//!
//! * `parallel/wall-double-b{N}/cores-{c}` — real wall time of
//!   `Executor::execute_batch` on this machine. Machine-dependent (CI
//!   runners may have fewer cores than workers), so these rows are
//!   excluded from baseline ratio gating.
//! * `parallel/model-scaling-b{N}-{c}core` — the deterministic makespan
//!   model over the executor's **actual** [`chunk_plan`] split: an ideal
//!   `c`-core machine runs `ceil(n_chunks / c)` chunk-waves of
//!   `chunk × tiles_per_op` tile-cycles (plus the ragged tail on the
//!   submitter), at a nominal 1 GHz. Machine-*independent* — the CI gate
//!   (`python/tools/check_bench.py`) enforces that each batch row is
//!   monotonically non-increasing in cores and that the largest batch
//!   reaches ≥ 2x at 4 cores, so a regression in the splitting policy
//!   (chunks too coarse to spread, threshold misrouting) fails the PR.
//!
//! Correctness is cross-checked against the sequential path before any
//! timing. `CIVP_BENCH_QUICK=1` shrinks iteration counts for CI smoke.

use civp::benchx::{bb, bench, scaled, section, JsonReport, Measurement};
use civp::decomp::{chunk_plan, ExecStats, Executor, OpClass, PlanCache, SchemeKind, LANES};
use civp::proput::Rng;
use civp::wideint::{U128, U256};

const CORES: [usize; 4] = [1, 2, 4, 8];
const SIZES: [usize; 3] = [128, 1024, 8192];
const THRESHOLD: usize = 256;

/// Ideal-`cores` makespan of one `n`-element double-precision batch, in
/// nanoseconds per op at 1 tile-cycle = 1 ns: below the threshold the
/// batch runs sequentially (`n` element-slots); above it the executor's
/// own `chunk_plan` split runs in `ceil(n_chunks / cores)` waves of one
/// chunk each, with the ragged tail on the submitting thread.
fn model_row(n: usize, cores: usize, tiles_per_op: u64) -> Measurement {
    let full = n - n % LANES;
    let tail = n - full;
    let (chunk, n_chunks) = chunk_plan(full, cores, LANES);
    let element_slots = if n < THRESHOLD || n_chunks < 2 {
        n
    } else {
        n_chunks.div_ceil(cores) * chunk + tail
    };
    let cycles_total = element_slots as u64 * tiles_per_op;
    Measurement::uniform(cycles_total as f64 / n as f64, n as u64)
}

fn main() {
    let mut json = JsonReport::new();
    let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);

    // Tiles per double multiply, taken from the plan itself so the model
    // tracks the real scheme (CIVP double = [24,24,9] x [24,24,9] tiles).
    let mut probe = ExecStats::default();
    let mut rng = Rng::new(0x9A7);
    plan.execute(rng.sig(53), rng.sig(53), &mut probe);
    let tiles_per_op = probe.tiles;

    section("multi-core wall time: Executor::execute_batch (double, CIVP)");
    for &n in &SIZES {
        let a: Vec<U128> = (0..n).map(|_| rng.sig(53)).collect();
        let b: Vec<U128> = (0..n).map(|_| rng.sig(53)).collect();
        // Sequential oracle once per size.
        let mut seq_stats = ExecStats::default();
        let mut want: Vec<U256> = Vec::new();
        plan.execute_batch(&a, &b, &mut seq_stats, &mut want);
        for &cores in &CORES {
            let exec = Executor::with_threshold(cores, THRESHOLD);
            // Cross-check before timing: bit-identical products + stats.
            let mut par_stats = ExecStats::default();
            let mut out: Vec<U256> = Vec::new();
            exec.execute_batch(&plan, &a, &b, &mut par_stats, &mut out);
            assert_eq!(out, want, "parallel diverged at n={n} cores={cores}");
            assert_eq!(par_stats.tiles, seq_stats.tiles, "stats diverged at n={n}");

            let iters = scaled(20_000 / n.max(1) as u64).max(2);
            let m = bench(&format!("b{n:<5} cores={cores} x{n}"), 5, 20, iters, || {
                exec.execute_batch(&plan, &a, &b, &mut par_stats, &mut out);
                bb(out.len());
            });
            json.push(&format!("parallel/wall-double-b{n}/cores-{cores}"), m);
        }
    }

    section("deterministic chunk-plan makespan model @ 1 tile-cycle/ns");
    let mut ok = true;
    for &n in &SIZES {
        let mut prev = f64::INFINITY;
        let mut at: Vec<(usize, f64)> = Vec::new();
        for &cores in &CORES {
            let m = model_row(n, cores, tiles_per_op);
            if m.ns_per_op_p50 > prev {
                ok = false;
            }
            prev = m.ns_per_op_p50;
            at.push((cores, m.ns_per_op_p50));
            json.push(&format!("parallel/model-scaling-b{n}-{cores}core"), m);
        }
        let base = at[0].1;
        let line: Vec<String> =
            at.iter().map(|(c, p)| format!("{c}c: {:.2}x", base / p)).collect();
        println!("b{n:<5} {}", line.join("  "));
        if n == *SIZES.last().unwrap() {
            let four = at.iter().find(|(c, _)| *c == 4).unwrap().1;
            if base / four < 2.0 {
                ok = false;
            }
        }
    }
    println!(
        "\n{}",
        if ok {
            "PASS: model speedup is monotonic in cores and >= 2x at 4 cores on the largest batch"
        } else {
            "FAIL: the chunk-plan split does not spread across cores as required"
        }
    );
    assert!(ok, "parallel-efficiency invariant violated");

    json.write("BENCH_parallel.json").expect("write BENCH_parallel.json");
}
