//! E2 — §II.A: single-precision multiplication, CIVP (one 24x24 block) vs
//! the existing 18x18 fabric (four blocks) vs 25x18 and 9x9 baselines.
//!
//! Reports (a) the static block/utilization table for one SP multiply and
//! (b) measured software throughput of the full IEEE pipeline under each
//! decomposition (the decomposition cost is the variable; the pipeline is
//! shared).

use civp::benchx::{bb, bench, section};
use civp::decomp::{scheme_census, DecompMul, OpClass, Scheme, SchemeKind};
use civp::fabric::{schedule_op, CostModel, FabricConfig};
use civp::fpu::{Fp32, RoundMode};
use civp::proput::Rng;

fn main() {
    section("E2 static: blocks per single-precision multiply (paper §II.A)");
    println!(
        "{:<10} {:>7} {:>8} {:>8} {:>10} {:>10}",
        "scheme", "blocks", "padded", "util%", "energy", "lat(cyc)"
    );
    let cost = CostModel::default();
    for kind in SchemeKind::ALL {
        let scheme = Scheme::new(kind, OpClass::Single);
        let census = scheme_census(&scheme);
        let fabric = match kind {
            SchemeKind::Civp => FabricConfig::civp_default(),
            _ => FabricConfig::legacy_default(),
        };
        let sched = schedule_op(&scheme, &fabric, &cost);
        println!(
            "{:<10} {:>7} {:>8} {:>8.1} {:>10.3} {:>10}",
            kind.name(),
            census.total_blocks,
            census.padded_blocks,
            census.utilization * 100.0,
            sched.dyn_energy,
            sched.latency_cycles
        );
    }
    println!("\npaper: one 24x24 block replaces four 18x18 blocks for SP [2].");

    section("E2 measured: software IEEE fp32 pipeline throughput per scheme");
    let mut rng = Rng::new(0xE2);
    let pairs: Vec<(Fp32, Fp32)> = (0..1024)
        .map(|_| (Fp32(rng.nasty_bits32()), Fp32(rng.nasty_bits32())))
        .collect();
    for kind in SchemeKind::ALL {
        let mut m = DecompMul::new(kind);
        let mut i = 0;
        bench(&format!("fp32 mul via {}", kind.name()), 2_000, 30, 20_000, || {
            let (a, b) = pairs[i & 1023];
            i += 1;
            bb(a.mul_with(b, RoundMode::NearestEven, &mut m));
        });
    }
    let mut direct = civp::fpu::DirectMul;
    let mut i = 0;
    bench("fp32 mul via direct (no decomposition)", 2_000, 30, 20_000, || {
        let (a, b) = pairs[i & 1023];
        i += 1;
        bb(a.mul_with(b, RoundMode::NearestEven, &mut direct));
    });
    let mut i = 0;
    bench("fp32 mul native hardware (reference)", 2_000, 30, 20_000, || {
        let (a, b) = pairs[i & 1023];
        i += 1;
        bb(a.to_f32() * b.to_f32());
    });
}
