//! Cluster scaling benchmark: aggregate throughput of the sharded
//! multi-fabric serving layer at 1/2/4/8 shards under the `mixed`
//! workload, plus a policy comparison at 4 shards.
//!
//! Two families of measurements land in `BENCH_cluster.json`:
//!
//! * `cluster/mixed/wall-*` — real submit→response wall-clock through the
//!   full stack (threads, batchers, backends). Machine-dependent.
//! * `cluster/mixed/model-scaling-*` — the deterministic fabric model:
//!   the trace's per-class op counts split evenly across N one-column
//!   CIVP fabrics, each run through the closed-form `simulate_counts`,
//!   aggregated with parallel-makespan semantics (wall cycles = slowest
//!   shard) at a nominal 1 GHz clock. Machine-*independent* — the CI
//!   bench gate (`python/tools/check_bench.py`) checks this curve is
//!   monotonically increasing in ops/sec from 1 → 4 shards.
//!
//! `CIVP_BENCH_QUICK=1` shrinks the trace for CI smoke runs.

use civp::benchx::{scaled, section, wall_measurement, JsonReport, Measurement};
use civp::cluster::{Cluster, ClusterConfig, RouterPolicy};
use civp::config::ServiceConfig;
use civp::coordinator::BackendChoice;
use civp::decomp::SchemeKind;
use civp::fabric::{simulate_counts, CostModel, FabricConfig, FabricOp};
use civp::trace::{TraceGen, TraceRequest, WorkloadSpec};
use std::collections::BTreeMap;
use std::time::Instant;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn cluster_cfg(shards: usize, policy: RouterPolicy) -> ClusterConfig {
    ClusterConfig {
        shards,
        // One worker per precision queue per shard keeps the thread count
        // proportional to the shard count — the scaling signal under test.
        service: ServiceConfig { workers: 1, ..Default::default() },
        policy,
        max_inflight: 4096,
        spares_per_block: 2,
    }
}

/// Drive the whole trace through a cluster and return the wall seconds.
/// Held replies are capped at half one shard's in-flight budget so the
/// blocking submit can never livelock on slots pinned by our own backlog.
fn drive(cluster: &Cluster, trace: &[TraceRequest]) -> f64 {
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(2048);
    for req in trace {
        let rx = cluster
            .submit(req.id, req.class, req.a, req.b)
            .expect("cluster open");
        pending.push(rx);
        if pending.len() >= 2048 {
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    t0.elapsed().as_secs_f64()
}

/// Deterministic fabric-model scaling: split the per-class counts evenly
/// across `n` single-column CIVP shards, report the aggregate at 1 GHz.
fn model_scaling(counts: &BTreeMap<FabricOp, u64>, n: u64, cost: &CostModel) -> Measurement {
    let fabric = FabricConfig::civp_scaled(1);
    let mut wall_cycles = 0u64;
    let mut total_ops = 0u64;
    for shard in 0..n {
        let mut share: BTreeMap<FabricOp, u64> = BTreeMap::new();
        for (class, &count) in counts {
            let mine = count / n + u64::from(shard < count % n);
            if mine > 0 {
                share.insert(*class, mine);
            }
        }
        if share.is_empty() {
            continue;
        }
        let report = simulate_counts(&share, &fabric, cost);
        wall_cycles = wall_cycles.max(report.cycles);
        total_ops += report.total_ops;
    }
    // 1 GHz nominal clock: one cycle = one nanosecond.
    let ns_per_op = wall_cycles as f64 / total_ops.max(1) as f64;
    Measurement {
        ns_per_op_p50: ns_per_op,
        ns_per_op_mean: ns_per_op,
        ns_per_op_min: ns_per_op,
        total_ops,
    }
}

fn main() {
    let mut json = JsonReport::new();
    let n_requests = scaled(40_000) as usize;
    let trace = TraceGen::new(0xC1, WorkloadSpec::Mixed.mix(), 0).take(n_requests);
    let mut counts: BTreeMap<FabricOp, u64> = BTreeMap::new();
    for r in &trace {
        *counts
            .entry(FabricOp { class: r.class, organization: SchemeKind::Civp })
            .or_insert(0) += 1;
    }
    let cost = CostModel::default();

    section("cluster scaling (mixed workload): wall-clock through the full stack");
    for shards in SHARD_COUNTS {
        let cluster = Cluster::start(
            &cluster_cfg(shards, RouterPolicy::LeastLoaded),
            BackendChoice::native(SchemeKind::Civp),
        );
        let wall = drive(&cluster, &trace);
        let report = cluster.shutdown();
        assert_eq!(report.total_ops, n_requests as u64, "cluster dropped ops");
        let m = wall_measurement(n_requests as u64, wall);
        println!(
            "{shards} shard(s): {:>10.0} mult/s wall  ({n_requests} reqs in {wall:.3}s, {} spilled)",
            m.ops_per_sec(),
            report.spilled
        );
        json.push(&format!("cluster/mixed/wall-{shards}shard"), m);
    }

    section("cluster scaling (mixed workload): deterministic fabric model @ 1 GHz");
    let mut last_ops_per_sec = 0.0;
    let mut monotonic = true;
    for shards in SHARD_COUNTS {
        let m = model_scaling(&counts, shards as u64, &cost);
        println!(
            "{shards} shard(s): {:>12.0} model ops/s  ({:.3} ns/op aggregate)",
            m.ops_per_sec(),
            m.ns_per_op_p50
        );
        if m.ops_per_sec() < last_ops_per_sec {
            monotonic = false;
        }
        last_ops_per_sec = m.ops_per_sec();
        json.push(&format!("cluster/mixed/model-scaling-{shards}shard"), m);
    }
    assert!(monotonic, "fabric-model aggregate throughput must scale with shard count");

    section("policy comparison at 4 shards (mixed workload)");
    for policy in RouterPolicy::ALL {
        let cluster =
            Cluster::start(&cluster_cfg(4, policy), BackendChoice::native(SchemeKind::Civp));
        let wall = drive(&cluster, &trace);
        let report = cluster.shutdown();
        assert_eq!(report.total_ops, n_requests as u64);
        let m = wall_measurement(n_requests as u64, wall);
        println!(
            "{:<20} {:>10.0} mult/s wall  ({} spilled, {} rejected)",
            policy.name(),
            m.ops_per_sec(),
            report.spilled,
            report.rejected_saturated
        );
        json.push(&format!("cluster/mixed/policy-{}-4shard", policy.name()), m);
    }

    json.write("BENCH_cluster.json").expect("write BENCH_cluster.json");
}
